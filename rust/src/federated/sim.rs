//! Event-driven asynchronous federation at simulated-million-client
//! scale.
//!
//! The synchronous loop ([`super::server`]) advances in lock-step
//! rounds; real cross-device deployments don't. This module simulates
//! the deployment regime the paper targets — extreme classification
//! over huge device fleets — with three pieces:
//!
//! 1. **A virtual client registry** ([`ClientRegistry`]): millions of
//!    client *records*, each a seeded latency/bandwidth profile derived
//!    on demand from `derive_seed(seed, PROFILE_TAG ^ id)`. No
//!    per-client allocation happens until a client is actually
//!    dispatched, so registry size is free — memory scales with the
//!    concurrency window, not the population. Registry ids map onto
//!    data shards via [`Partition::shard`] (wrap-around), so a
//!    million-client fleet trains over a K-shard partition.
//! 2. **A deterministic event clock**: a binary heap of
//!    [`Event`]s ordered by `(simulated time, dispatch sequence)` via
//!    `f64::total_cmp` — ties are impossible to mis-order, so the event
//!    trace (and therefore every downstream number) is bitwise
//!    reproducible for a fixed seed, independent of `--workers`.
//!    Client compute executes *at dispatch time* on the coordinator
//!    thread in deterministic event order; only its simulated duration
//!    is scheduled.
//! 3. **Buffered asynchronous aggregation** (FedBuff-style): arrivals
//!    accumulate in a buffer; once `--buffer K` land, the server folds
//!    the staleness-weighted mean delta into the globals and bumps its
//!    version. An update trained against version `v` applied at version
//!    `V` has staleness `V − v` and weight `(1 + V − v)^(-exp)`.
//!
//! Dropout is injected mid-round from a per-dispatch seeded RNG: a
//! dropped client is charged its *download* (the broadcast was sent)
//! but never uploads and never trains — the dispatch slot is simply
//! refilled.
//!
//! Fault injection (`--inject`, see [`super::fault`]) rides the same
//! discipline: transient upload failures retry with exponential backoff
//! on the simulated clock (bounded attempts) before the dispatch is
//! declared lost, and per-(dispatch, sub-model) payload fates corrupt,
//! truncate or NaN-poison arriving updates exactly as the synchronous
//! loop does. Every fate is a pure function of the seed, so an injected
//! run stays bitwise reproducible.
//!
//! All timing columns in the resulting [`History`] carry *simulated*
//! seconds (`train_seconds` = simulated compute, `encode_seconds` =
//! simulated transfer, `sim_seconds` = the event clock at aggregation),
//! which is what makes the async history CSV bitwise reproducible —
//! wall-clock never leaks into a record.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use crate::algo::LabelScheme;
use crate::config::{ExperimentConfig, RobustAgg};
use crate::data::dataset::{batch_ranges, Dataset};
use crate::data::stats::LabelStats;
use crate::model::params::ModelParams;
use crate::partition::Partition;
use crate::util::rng::{derive_seed, Rng};

use super::backend::TrainBackend;
use super::comm::CommMeter;
use super::early_stop::EarlyStopper;
use super::engine::{ClientUpdate, RoundEngine};
use super::fault::{self, FaultKind};
use super::history::{History, RoundRecord, RoundTiming};
use super::sampler::ClientSampler;
use super::server::{evaluate, RunOutput};
use super::transport::Transport;
use super::wire::EncodedUpdate;

/// Seed-stream tag for client profiles (xor'd with the client id).
const PROFILE_TAG: u64 = 0x51c0_b0de_0000_0000;
/// Seed-stream tag for per-dispatch dropout fate (xor'd with the seq).
const DROPOUT_TAG: u64 = 0xa51d_0000_0000_0000;

// ---------------------------------------------------------------------
// Latency / bandwidth distributions
// ---------------------------------------------------------------------

/// A positive-valued sampling distribution for client system profiles,
/// parseable from the CLI (`fixed:<v> | uniform:<lo>,<hi> |
/// lognormal:<median>,<sigma>`).
///
/// Log-normal is the default shape: device speed and link quality in
/// real fleets are heavy-tailed, and the straggler tail is exactly what
/// asynchronous aggregation exists to absorb.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dist {
    /// Every sample is `value`.
    Fixed { value: f64 },
    /// Uniform on `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
    /// `median * exp(sigma * N(0,1))` — median-parameterized so the
    /// CLI number is directly interpretable.
    LogNormal { median: f64, sigma: f64 },
}

impl Dist {
    /// Parse a CLI spec. Inverse of [`Dist::name`].
    pub fn parse(s: &str) -> Result<Dist> {
        let (kind, rest) = s.split_once(':').unwrap_or((s, ""));
        let args: Vec<f64> = if rest.is_empty() {
            vec![]
        } else {
            rest.split(',')
                .map(|a| {
                    a.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("bad number '{a}' in distribution '{s}'"))
                })
                .collect::<Result<_>>()?
        };
        let dist = match (kind, args.as_slice()) {
            ("fixed", [value]) => Dist::Fixed { value: *value },
            ("uniform", [lo, hi]) => Dist::Uniform { lo: *lo, hi: *hi },
            ("lognormal", [median, sigma]) => Dist::LogNormal {
                median: *median,
                sigma: *sigma,
            },
            _ => bail!(
                "unknown distribution '{s}' \
                 (expected fixed:<v> | uniform:<lo>,<hi> | lognormal:<median>,<sigma>)"
            ),
        };
        dist.validate()?;
        Ok(dist)
    }

    /// The canonical spec string ([`Dist::parse`] round-trips it).
    pub fn name(&self) -> String {
        match self {
            Dist::Fixed { value } => format!("fixed:{value}"),
            Dist::Uniform { lo, hi } => format!("uniform:{lo},{hi}"),
            Dist::LogNormal { median, sigma } => format!("lognormal:{median},{sigma}"),
        }
    }

    /// Parameters must yield strictly positive samples.
    pub fn validate(&self) -> Result<()> {
        let ok = match self {
            Dist::Fixed { value } => *value > 0.0,
            Dist::Uniform { lo, hi } => *lo > 0.0 && *hi >= *lo,
            Dist::LogNormal { median, sigma } => *median > 0.0 && *sigma >= 0.0,
        };
        if !ok {
            bail!("distribution '{}' needs positive parameters", self.name());
        }
        Ok(())
    }

    /// Draw one sample (always `> 0` for validated parameters).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Dist::Fixed { value } => *value,
            Dist::Uniform { lo, hi } => rng.range_f64(*lo, *hi),
            Dist::LogNormal { median, sigma } => median * (sigma * rng.gaussian()).exp(),
        }
    }
}

// ---------------------------------------------------------------------
// Virtual client registry
// ---------------------------------------------------------------------

/// One client's system profile — derived, never stored.
#[derive(Clone, Copy, Debug)]
pub struct ClientProfile {
    /// Simulated seconds to run one local epoch.
    pub compute_seconds_per_epoch: f64,
    /// Downlink throughput in bytes per simulated second.
    pub down_bytes_per_second: f64,
    /// Uplink throughput in bytes per simulated second.
    pub up_bytes_per_second: f64,
}

/// A population of virtual clients addressed by id in `[0, clients)`.
///
/// Profiles are a pure function of `(seed, id)`, so a million-client
/// registry costs 4 words: sampling client 782_113 twice — even across
/// separate runs — yields the identical profile without any state.
#[derive(Clone, Copy, Debug)]
pub struct ClientRegistry {
    clients: usize,
    seed: u64,
    latency: Dist,
    bandwidth: Dist,
}

impl ClientRegistry {
    pub fn new(clients: usize, seed: u64, latency: Dist, bandwidth: Dist) -> Self {
        assert!(clients > 0, "registry needs at least one client");
        ClientRegistry {
            clients,
            seed,
            latency,
            bandwidth,
        }
    }

    pub fn len(&self) -> usize {
        self.clients
    }

    pub fn is_empty(&self) -> bool {
        self.clients == 0
    }

    /// Derive client `id`'s profile. Latency samples are seconds per
    /// epoch; bandwidth samples are Mbit/s, converted to bytes/s (down
    /// and up drawn independently from the same distribution).
    pub fn profile(&self, id: usize) -> ClientProfile {
        debug_assert!(id < self.clients);
        let mut rng = Rng::new(derive_seed(self.seed, PROFILE_TAG ^ id as u64));
        let compute = self.latency.sample(&mut rng);
        let down_mbps = self.bandwidth.sample(&mut rng);
        let up_mbps = self.bandwidth.sample(&mut rng);
        ClientProfile {
            compute_seconds_per_epoch: compute,
            down_bytes_per_second: down_mbps * 1e6 / 8.0,
            up_bytes_per_second: up_mbps * 1e6 / 8.0,
        }
    }
}

// ---------------------------------------------------------------------
// Staleness-weighted buffered aggregation
// ---------------------------------------------------------------------

/// FedBuff-style staleness discount: an update trained against a base
/// `staleness` versions behind the server weighs
/// `(1 + staleness)^(-exp)`. `exp = 0` disables the discount;
/// `exp = 0.5` is the literature's default.
pub fn staleness_weight(staleness: u64, exp: f64) -> f64 {
    (1.0 + staleness as f64).powf(-exp)
}

/// One arrived client update, reduced to its per-sub-model deltas
/// (decoded update − broadcast base) and its staleness weight.
#[derive(Clone, Debug)]
pub struct WeightedUpdate {
    pub weight: f64,
    pub staleness: u64,
    /// Per-sub-model delta the client contributed.
    pub deltas: Vec<ModelParams>,
}

/// Fold one buffer of weighted updates into the globals:
/// `global_j += Σ_i w_i · δ_ij / Σ_i w_i` for each sub-model `j`.
pub fn apply_buffered(globals: &mut [ModelParams], buffer: &[WeightedUpdate]) -> Result<()> {
    if buffer.is_empty() {
        bail!("buffered aggregation over an empty buffer");
    }
    let w_sum: f64 = buffer.iter().map(|u| u.weight).sum();
    if !(w_sum > 0.0) {
        bail!("staleness weights sum to {w_sum}, expected > 0");
    }
    for (j, global) in globals.iter_mut().enumerate() {
        for u in buffer {
            global.accumulate(&u.deltas[j], (u.weight / w_sum) as f32)?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// The event queue
// ---------------------------------------------------------------------

/// Counters a finished async run reports alongside the usual output.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimStats {
    /// Clients dispatched (each charged a download).
    pub dispatched: u64,
    /// Updates that arrived back (each charged an upload).
    pub arrived: u64,
    /// Dispatches lost to mid-round dropout (download only).
    pub dropped: u64,
    /// Dispatches whose upload never completed after every retry
    /// (`--inject fail:<p>`): the client trained and was charged its
    /// download, but nothing arrived.
    pub failed: u64,
    /// Buffered aggregations applied (= final server version).
    pub aggregations: u64,
    /// Simulated wall-clock at the end of the run.
    pub sim_seconds: f64,
    /// Mean staleness over arrived updates.
    pub mean_staleness: f64,
    /// Worst staleness any applied update carried.
    pub max_staleness: u64,
}

enum EventKind {
    /// A client's update lands at the server.
    Arrival {
        /// Server version the client's broadcast base was at.
        base_version: u64,
        /// The decoded broadcast bases the client trained from (one per
        /// sub-model) — needed to decode and difference the update.
        bases: Vec<ModelParams>,
        /// The trained, wire-encoded updates (one per sub-model).
        updates: Vec<ClientUpdate>,
        /// Simulated compute seconds the client spent.
        compute_seconds: f64,
        /// Simulated transfer seconds (download + upload).
        transfer_seconds: f64,
    },
    /// A dispatched client dies mid-round; nothing arrives.
    Dropout,
    /// A client exhausted its upload retries (`--inject fail:<p>`); it
    /// trained, but nothing arrives.
    Failed,
}

struct Event {
    /// Simulated time the event fires.
    time: f64,
    /// Dispatch sequence number — the deterministic tie-breaker.
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total_cmp gives f64 a total order (no NaN panics, -0 < +0),
        // and the seq tie-break makes simultaneous events deterministic.
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

// ---------------------------------------------------------------------
// The async loop
// ---------------------------------------------------------------------

struct AsyncLoop<'a> {
    cfg: &'a ExperimentConfig,
    scheme: &'a dyn LabelScheme,
    backend: &'a dyn TrainBackend,
    train: &'a Dataset,
    partition: &'a Partition,
    engine: RoundEngine,
    registry: ClientRegistry,
    sampler: ClientSampler,
    transport: Transport,
    comm: CommMeter,
    globals: Vec<ModelParams>,
    model_bytes_each: usize,
    n_models: usize,
    queue: BinaryHeap<Reverse<Event>>,
    /// The simulated clock — the time of the event being handled.
    now: f64,
    /// Server model version (= aggregations applied so far).
    version: u64,
    /// Monotone dispatch counter; doubles as sampler round and event
    /// tie-breaker.
    dispatch_seq: u64,
    buffer: Vec<WeightedUpdate>,
    // Per-aggregation-window accumulators (reset after each apply).
    window_start: f64,
    window_loss_sum: f64,
    window_loss_n: usize,
    window_train_seconds: f64,
    window_transfer_seconds: f64,
    down_mark: u64,
    up_mark: u64,
    stats: SimStats,
    staleness_sum_total: f64,
}

impl<'a> AsyncLoop<'a> {
    /// Trace lane for a dispatch: in-flight slots cycle through the
    /// `concurrency` lanes so concurrent clients render side by side in
    /// Perfetto instead of stacking on one row.
    fn trace_lane(&self, seq: u64) -> u64 {
        seq % self.cfg.sim.concurrency.max(1) as u64
    }

    /// Dispatch one sampled client: broadcast to it, charge the
    /// download, run its local training *now* (deterministic order),
    /// and schedule the arrival — or a dropout — on the event clock.
    fn dispatch(&mut self) -> Result<()> {
        let seq = self.dispatch_seq;
        self.dispatch_seq += 1;
        self.stats.dispatched += 1;

        let client = self.sampler.sample(seq as usize)[0];
        let profile = self.registry.profile(client);

        let bcast = self.transport.broadcast(seq as usize, &[client], &self.globals)?;
        let mut down_bytes = 0u64;
        for j in 0..self.n_models {
            let b = bcast.payload(0, j).byte_len();
            self.comm.download_encoded(b, self.model_bytes_each);
            down_bytes += b as u64;
        }
        let t_down = down_bytes as f64 / profile.down_bytes_per_second;
        let t_compute = profile.compute_seconds_per_epoch * self.cfg.local_epochs as f64;

        // Per-dispatch fate stream: one bernoulli, and — only when it
        // fires — a mid-compute fraction for the death time.
        let mut fate = Rng::new(derive_seed(self.cfg.seed, DROPOUT_TAG ^ seq));
        if fate.bernoulli(self.cfg.sim.dropout) {
            let death = self.now + t_down + fate.next_f64() * t_compute;
            if crate::obs::trace::enabled() {
                crate::obs::trace::sim_span(
                    "client dropout",
                    self.trace_lane(seq),
                    self.now,
                    death,
                    vec![(
                        "client".to_string(),
                        crate::util::json::Json::num(client as f64),
                    )],
                );
            }
            self.queue.push(Reverse(Event {
                time: death,
                seq,
                kind: EventKind::Dropout,
            }));
            return Ok(());
        }

        // Local training executes here, at dispatch time, in event
        // order — so results never depend on how simulated arrivals
        // interleave, and the engine's worker-count invariance carries
        // over unchanged.
        let grouped = self.engine.run_round(
            self.cfg,
            self.scheme,
            self.backend,
            self.transport.uplink(),
            self.train,
            self.partition,
            &bcast,
            seq as usize,
            &[client],
        )?;
        let updates = grouped
            .into_iter()
            .next()
            .expect("one selected client yields one update group");
        let bases: Vec<ModelParams> = (0..self.n_models)
            .map(|j| bcast.global(0, j).clone())
            .collect();
        let up_bytes: u64 = updates.iter().map(|u| u.encoded.byte_len() as u64).sum();
        let t_up = up_bytes as f64 / profile.up_bytes_per_second;

        // Injected transient upload failures (`--inject fail:<p>`): the
        // client retries with exponential backoff on the simulated
        // clock, each retry re-paying the upload in *time*; bytes are
        // only charged for an attempt that lands. Zero RNG draws at
        // rate 0, so clean runs are untouched.
        let (retries, lost) = fault::retry_plan(&self.cfg.inject, self.cfg.seed, seq);
        let mut retry_seconds = 0.0;
        for attempt in 1..=retries {
            retry_seconds += fault::backoff_seconds(attempt) + t_up;
        }
        let arrival = self.now + t_down + t_compute + t_up + retry_seconds;
        if lost {
            fault::record(FaultKind::Fail);
            if crate::obs::trace::enabled() {
                crate::obs::trace::sim_span(
                    "client failed",
                    self.trace_lane(seq),
                    self.now,
                    arrival,
                    vec![(
                        "client".to_string(),
                        crate::util::json::Json::num(client as f64),
                    )],
                );
            }
            self.queue.push(Reverse(Event {
                time: arrival,
                seq,
                kind: EventKind::Failed,
            }));
            return Ok(());
        }

        // Simulated-clock lifecycle spans: the trace shows what the
        // *virtual* timeline looked like (stragglers stretch the train
        // span, slow links stretch the transfers), not the wall time the
        // simulator spent computing it.
        if crate::obs::trace::enabled() {
            let lane = self.trace_lane(seq);
            let args = vec![(
                "client".to_string(),
                crate::util::json::Json::num(client as f64),
            )];
            let t0 = self.now;
            crate::obs::trace::sim_span("download", lane, t0, t0 + t_down, args.clone());
            crate::obs::trace::sim_span(
                "train",
                lane,
                t0 + t_down,
                t0 + t_down + t_compute,
                args.clone(),
            );
            crate::obs::trace::sim_span("upload", lane, t0 + t_down + t_compute, arrival, args);
        }

        self.queue.push(Reverse(Event {
            time: arrival,
            seq,
            kind: EventKind::Arrival {
                base_version: self.version,
                bases,
                updates,
                compute_seconds: t_compute,
                transfer_seconds: t_down + t_up + retry_seconds,
            },
        }));
        Ok(())
    }

    /// An update landed: charge the upload, decode each sub-model
    /// against the base the client trained from, difference into a
    /// delta, and push the staleness-weighted result into the buffer.
    /// Injected payload faults (`--inject`) strike here: an undecodable
    /// sub-model contributes a zero delta (bytes already charged), a
    /// NaN-poisoned one is left for `--robust-agg` to screen.
    fn on_arrival(
        &mut self,
        seq: u64,
        base_version: u64,
        bases: Vec<ModelParams>,
        updates: Vec<ClientUpdate>,
        compute_seconds: f64,
        transfer_seconds: f64,
    ) -> Result<()> {
        self.stats.arrived += 1;
        let staleness = self.version.saturating_sub(base_version);
        self.staleness_sum_total += staleness as f64;
        self.stats.max_staleness = self.stats.max_staleness.max(staleness);
        self.window_train_seconds += compute_seconds;
        self.window_transfer_seconds += transfer_seconds;

        let inject_payloads = self.cfg.inject.corrupt > 0.0
            || self.cfg.inject.truncate > 0.0
            || self.cfg.inject.nan > 0.0;
        let mut deltas = Vec::with_capacity(self.n_models);
        for (j, upd) in updates.iter().enumerate() {
            self.comm
                .upload_encoded(upd.encoded.byte_len(), self.model_bytes_each);
            let delta = if inject_payloads {
                // Per-(dispatch, sub-model) fate stream — `seq` plays
                // the role the sync loop's (round, client) pair plays.
                let stream = seq
                    .wrapping_mul(self.n_models as u64)
                    .wrapping_add(j as u64);
                self.inject_delta(&bases[j], &upd.encoded, stream)?
            } else {
                Some(self.decode_delta(&bases[j], &upd.encoded)?)
            };
            deltas.push(match delta {
                Some(d) => d,
                None => ModelParams::zeros(bases[j].d, bases[j].hidden, bases[j].out),
            });
            if upd.stats.steps > 0 {
                self.window_loss_sum += upd.stats.mean_loss;
                self.window_loss_n += 1;
            }
        }
        screen_deltas(&mut deltas, self.cfg.robust);
        self.buffer.push(WeightedUpdate {
            weight: staleness_weight(staleness, self.cfg.sim.staleness_exp),
            staleness,
            deltas,
        });
        Ok(())
    }

    /// Decode one sub-model update and difference it into a delta.
    fn decode_delta(&self, base: &ModelParams, enc: &EncodedUpdate) -> Result<ModelParams> {
        let mut decoded = self.transport.decode(base, enc)?;
        decoded.accumulate(base, -1.0)?;
        Ok(decoded)
    }

    /// Async counterpart of the sync loop's fate application: draw the
    /// payload fate for one `(dispatch, sub-model)` item; corrupt and
    /// truncate mutate the *framed* wire bytes so the checksummed
    /// decode rejects them (`Ok(None)` — the contribution is
    /// discarded), NaN poisons the decoded update, a clean fate decodes
    /// normally.
    fn inject_delta(
        &self,
        base: &ModelParams,
        enc: &EncodedUpdate,
        stream: u64,
    ) -> Result<Option<ModelParams>> {
        let (fate, mut rng) = fault::payload_fate(&self.cfg.inject, self.cfg.seed, stream);
        match fate {
            Some(kind @ (FaultKind::Corrupt | FaultKind::Truncate)) => {
                let mut bytes = enc.to_framed_bytes();
                match kind {
                    FaultKind::Corrupt => fault::corrupt_bytes(&mut bytes, &mut rng),
                    _ => fault::truncate_bytes(&mut bytes, &mut rng),
                }
                let spec = self.transport.uplink().spec();
                let parsed = EncodedUpdate::from_framed_bytes(
                    spec,
                    base.tensors.len(),
                    base.num_params(),
                    &bytes,
                );
                match parsed {
                    Ok(ok) => Ok(Some(self.decode_delta(base, &ok)?)),
                    Err(_) => {
                        fault::record(kind);
                        Ok(None)
                    }
                }
            }
            Some(FaultKind::Nan) => {
                let mut decoded = self.transport.decode(base, enc)?;
                fault::poison_nan(&mut decoded);
                fault::record(FaultKind::Nan);
                decoded.accumulate(base, -1.0)?;
                Ok(Some(decoded))
            }
            _ => Ok(Some(self.decode_delta(base, enc)?)),
        }
    }
}

/// Defensive screening for the async path (`--robust-agg`): zero out
/// non-finite deltas (counted in `fedmlh_robust_screened_total`) and,
/// under norm-clip, bound each surviving delta's L2 norm at `c`. The
/// coordinate-wise trimmed mean needs a full round of aligned updates,
/// which buffered asynchronous aggregation never holds — `trimmed`
/// degrades to screening here.
pub fn screen_deltas(deltas: &mut [ModelParams], robust: RobustAgg) {
    if matches!(robust, RobustAgg::None) {
        return;
    }
    let mut screened = 0u64;
    for delta in deltas.iter_mut() {
        let finite = delta
            .tensors
            .iter()
            .all(|t| t.data().iter().all(|v| v.is_finite()));
        if !finite {
            for t in delta.tensors.iter_mut() {
                t.fill(0.0);
            }
            screened += 1;
            continue;
        }
        if let RobustAgg::NormClip { c } = robust {
            let norm = delta
                .tensors
                .iter()
                .flat_map(|t| t.data())
                .map(|&v| f64::from(v) * f64::from(v))
                .sum::<f64>()
                .sqrt();
            if norm > c {
                let scale = (c / norm) as f32;
                for t in delta.tensors.iter_mut() {
                    for v in t.data_mut() {
                        *v *= scale;
                    }
                }
            }
        }
    }
    if screened > 0 {
        crate::obs::metrics::global()
            .counter(
                "fedmlh_robust_screened_total",
                "Non-finite client updates screened out by --robust-agg.",
            )
            .add(screened);
    }
}

/// Run one asynchronous federated experiment on the event clock.
///
/// The output mirrors [`super::server::run`]: a [`History`] row per
/// buffered aggregation (a "round" in async terms), exact per-client
/// communication metering, early stopping on mean top-k — plus
/// [`SimStats`] in `RunOutput::sim`. For a fixed `cfg.seed` the entire
/// output is bitwise reproducible, including across `--workers` counts.
pub fn run_async(
    cfg: &ExperimentConfig,
    scheme: &dyn LabelScheme,
    backend: &dyn TrainBackend,
    train: &Dataset,
    test: &Dataset,
    partition: &Partition,
) -> Result<RunOutput> {
    cfg.validate()?;
    if !cfg.sim.async_mode {
        bail!("run_async called with sim.async_mode = false; use server::run");
    }
    let t_start = std::time::Instant::now();
    let n_models = scheme.n_models();
    let out_dim = scheme.out_dim();
    let batch = cfg.preset.batch;

    // Same init streams as the synchronous loop: a sync and an async
    // run of one config start from identical globals.
    let globals: Vec<ModelParams> = (0..n_models)
        .map(|j| {
            ModelParams::init(
                train.d(),
                cfg.preset.hidden,
                out_dim,
                derive_seed(cfg.seed, 0x1417_0000 + j as u64),
            )
        })
        .collect();
    let model_bytes_each = globals[0].byte_size();

    let registry_n = if cfg.sim.registry == 0 {
        cfg.clients
    } else {
        cfg.sim.registry
    };
    let registry = ClientRegistry::new(registry_n, cfg.seed, cfg.sim.latency, cfg.sim.bandwidth);

    let mut state = AsyncLoop {
        cfg,
        scheme,
        backend,
        train,
        partition,
        engine: RoundEngine::new(cfg.workers),
        registry,
        // One draw per dispatch; `seq` plays the sampler's round role.
        sampler: ClientSampler::new(registry_n, 1, cfg.seed),
        transport: Transport::new(cfg, n_models)?,
        comm: CommMeter::new(),
        globals,
        model_bytes_each,
        n_models,
        queue: BinaryHeap::new(),
        now: 0.0,
        version: 0,
        dispatch_seq: 0,
        buffer: Vec::with_capacity(cfg.sim.buffer),
        window_start: 0.0,
        window_loss_sum: 0.0,
        window_loss_n: 0,
        window_train_seconds: 0.0,
        window_transfer_seconds: 0.0,
        down_mark: 0,
        up_mark: 0,
        stats: SimStats::default(),
        staleness_sum_total: 0.0,
    };

    let mut history = History::new();
    let mut stopper = EarlyStopper::new(cfg.patience);
    let train_stats = LabelStats::from_dataset(train);
    let frequent_k = partition.class_owner.len().max(1);
    let test_batches = batch_ranges(test.len(), batch);

    // Event-loop instrumentation (observational only: updated from
    // state the loop already computes, never read back).
    let obs = crate::obs::metrics::global();
    let m_aggregations = obs.counter(
        "fedmlh_sim_aggregations_total",
        "Buffered async aggregations applied.",
    );
    let m_staleness = obs.histogram(
        "fedmlh_sim_staleness",
        "Staleness (server versions behind) of aggregated updates.",
        &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0],
    );
    let m_clock = obs.gauge(
        "fedmlh_sim_clock_seconds",
        "Simulated clock at the latest aggregation.",
    );

    // Generous dispatch ceiling so a pathological dropout draw can't
    // spin forever; validation already caps dropout below 1.
    let needed = (cfg.rounds * cfg.sim.buffer) as f64;
    let max_dispatch =
        (needed / (1.0 - cfg.sim.dropout) * 64.0) as u64 + cfg.sim.concurrency as u64 + 1024;

    // Prime the concurrency window.
    for _ in 0..cfg.sim.concurrency {
        state.dispatch()?;
    }

    loop {
        let Some(Reverse(ev)) = state.queue.pop() else {
            bail!(
                "event queue drained after {} dispatches with only {}/{} aggregations \
                 — concurrency {} cannot fill buffer {}",
                state.dispatch_seq,
                state.version,
                cfg.rounds,
                cfg.sim.concurrency,
                cfg.sim.buffer
            );
        };
        state.now = ev.time;
        let seq = ev.seq;
        match ev.kind {
            EventKind::Dropout => state.stats.dropped += 1,
            EventKind::Failed => state.stats.failed += 1,
            EventKind::Arrival {
                base_version,
                bases,
                updates,
                compute_seconds,
                transfer_seconds,
            } => state.on_arrival(
                seq,
                base_version,
                bases,
                updates,
                compute_seconds,
                transfer_seconds,
            )?,
        }

        // Buffer full → staleness-weighted aggregation = one "round".
        if state.buffer.len() >= cfg.sim.buffer {
            let round = state.version as usize;
            let taken = std::mem::take(&mut state.buffer);
            apply_buffered(&mut state.globals, &taken)?;
            state.version += 1;
            state.stats.aggregations = state.version;
            m_aggregations.inc();
            for upd in &taken {
                m_staleness.observe(upd.staleness as f64);
            }
            m_clock.set(state.now);
            if crate::obs::trace::enabled() {
                crate::obs::trace::sim_instant(
                    "aggregate",
                    0,
                    state.now,
                    vec![(
                        "version".to_string(),
                        crate::util::json::Json::num(state.version as f64),
                    )],
                );
            }
            state.comm.end_round();
            let down_bytes = state.comm.downloaded() - state.down_mark;
            let up_bytes = state.comm.uploaded() - state.up_mark;

            let mut stop = false;
            if round % cfg.eval_every == 0 || state.version as usize == cfg.rounds {
                let report = evaluate(
                    scheme,
                    backend,
                    &state.globals,
                    test,
                    &train_stats,
                    frequent_k,
                    batch,
                    &test_batches,
                )?;
                history.push(RoundRecord {
                    round,
                    accuracy: report,
                    comm_bytes: state.comm.total(),
                    down_bytes,
                    up_bytes,
                    round_seconds: state.now - state.window_start,
                    mean_loss: if state.window_loss_n > 0 {
                        state.window_loss_sum / state.window_loss_n as f64
                    } else {
                        0.0
                    },
                    timing: RoundTiming {
                        train_seconds: state.window_train_seconds,
                        encode_seconds: state.window_transfer_seconds,
                        aggregate_seconds: 0.0,
                    },
                    sim_seconds: state.now,
                });
                stop = stopper.observe(round, report.mean_topk());
            }

            // Reset the aggregation window.
            state.window_start = state.now;
            state.window_loss_sum = 0.0;
            state.window_loss_n = 0;
            state.window_train_seconds = 0.0;
            state.window_transfer_seconds = 0.0;
            state.down_mark = state.comm.downloaded();
            state.up_mark = state.comm.uploaded();

            if stop || state.version as usize >= cfg.rounds {
                break;
            }
        }

        // Refill the dispatch window (the in-flight population stays at
        // `concurrency` minus whatever the ceiling clipped).
        if state.dispatch_seq < max_dispatch {
            state.dispatch()?;
        }
    }

    state.stats.sim_seconds = state.now;
    state.stats.mean_staleness = state.staleness_sum_total / state.stats.arrived.max(1) as f64;

    // Dispatch/arrival/dropout totals land in the registry once at the
    // end (the hot loop stays free of per-event registry traffic).
    obs.counter(
        "fedmlh_sim_dispatched_total",
        "Client dispatches issued by the async simulator.",
    )
    .add(state.stats.dispatched);
    obs.counter(
        "fedmlh_sim_arrived_total",
        "Client updates that arrived back.",
    )
    .add(state.stats.arrived);
    obs.counter(
        "fedmlh_sim_dropped_total",
        "Dispatches lost to mid-round dropout.",
    )
    .add(state.stats.dropped);
    obs.counter(
        "fedmlh_sim_failed_total",
        "Dispatches lost to injected upload failure after every retry.",
    )
    .add(state.stats.failed);

    let best_rec = *history
        .best()
        .ok_or_else(|| anyhow::anyhow!("no evaluation rounds recorded"))?;
    Ok(RunOutput {
        best: best_rec.accuracy,
        best_round: best_rec.round + 1,
        comm_to_best: best_rec.comm_bytes,
        rounds_run: state.version as usize,
        model_bytes: model_bytes_each * n_models,
        n_models,
        total_seconds: t_start.elapsed().as_secs_f64(),
        history,
        comm: state.comm,
        final_globals: state.globals,
        sim: Some(state.stats),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_parse_roundtrips_and_validates() {
        let cases = [
            ("fixed:2.5", Dist::Fixed { value: 2.5 }),
            ("uniform:1,4", Dist::Uniform { lo: 1.0, hi: 4.0 }),
            (
                "lognormal:2,0.7",
                Dist::LogNormal {
                    median: 2.0,
                    sigma: 0.7,
                },
            ),
        ];
        for (s, want) in cases {
            let d = Dist::parse(s).unwrap();
            assert_eq!(d, want, "{s}");
            assert_eq!(Dist::parse(&d.name()).unwrap(), d, "roundtrip {s}");
        }
        assert!(Dist::parse("gamma:1,2").is_err());
        assert!(Dist::parse("fixed:0").is_err(), "zero rejected");
        assert!(Dist::parse("uniform:3,1").is_err(), "hi < lo rejected");
        assert!(Dist::parse("lognormal:-1,0.5").is_err());
        assert!(Dist::parse("fixed:abc").is_err());
    }

    #[test]
    fn dist_samples_positive_and_shaped() {
        let mut rng = Rng::new(7);
        assert_eq!(Dist::Fixed { value: 3.0 }.sample(&mut rng), 3.0);
        let u = Dist::Uniform { lo: 2.0, hi: 5.0 };
        let ln = Dist::LogNormal {
            median: 2.0,
            sigma: 0.7,
        };
        for _ in 0..500 {
            let x = u.sample(&mut rng);
            assert!((2.0..5.0).contains(&x));
            assert!(ln.sample(&mut rng) > 0.0);
        }
        // sigma = 0 degenerates to the median exactly.
        let d = Dist::LogNormal {
            median: 4.0,
            sigma: 0.0,
        };
        assert_eq!(d.sample(&mut rng), 4.0);
    }

    #[test]
    fn registry_profiles_are_pure_and_lazy() {
        let reg = ClientRegistry::new(
            1_000_000,
            42,
            Dist::LogNormal {
                median: 2.0,
                sigma: 0.7,
            },
            Dist::LogNormal {
                median: 20.0,
                sigma: 0.8,
            },
        );
        assert_eq!(reg.len(), 1_000_000);
        let a = reg.profile(782_113);
        let b = reg.profile(782_113);
        assert_eq!(a.compute_seconds_per_epoch, b.compute_seconds_per_epoch);
        assert_eq!(a.down_bytes_per_second, b.down_bytes_per_second);
        assert_eq!(a.up_bytes_per_second, b.up_bytes_per_second);
        assert!(a.compute_seconds_per_epoch > 0.0);
        assert!(a.down_bytes_per_second > 0.0);
        // Different clients almost surely differ under a continuous dist.
        let c = reg.profile(782_114);
        assert_ne!(a.compute_seconds_per_epoch, c.compute_seconds_per_epoch);
    }

    #[test]
    fn staleness_weights_discount_correctly() {
        assert_eq!(staleness_weight(0, 0.5), 1.0);
        assert_eq!(staleness_weight(0, 2.0), 1.0);
        // (1+3)^-0.5 = 0.5 — powf goes through exp/ln, so compare approx
        assert!((staleness_weight(3, 0.5) - 0.5).abs() < 1e-12);
        assert_eq!(staleness_weight(7, 0.0), 1.0, "exp 0 disables");
        assert!(staleness_weight(10, 0.5) < staleness_weight(1, 0.5));
    }

    #[test]
    fn apply_buffered_takes_weighted_mean_of_deltas() {
        let mut globals = vec![ModelParams::zeros(2, 3, 4)];
        let mk = |v: f32, staleness: u64| {
            let mut d = ModelParams::zeros(2, 3, 4);
            for t in d.tensors.iter_mut() {
                t.fill(v);
            }
            WeightedUpdate {
                weight: staleness_weight(staleness, 0.5),
                staleness,
                deltas: vec![d],
            }
        };
        // weights 1.0 and (1+3)^-0.5 = 0.5 → (1·1 + 0.5·3)/1.5 = 5/3
        apply_buffered(&mut globals, &[mk(1.0, 0), mk(3.0, 3)]).unwrap();
        let got = globals[0].flat_values();
        for v in got {
            assert!((v - 5.0 / 3.0).abs() < 1e-5, "got {v}");
        }
        // Degenerate cases bail instead of corrupting the globals.
        assert!(apply_buffered(&mut globals, &[]).is_err());
    }

    #[test]
    fn screen_deltas_zeroes_nan_and_clips_norms() {
        let mut nan_d = ModelParams::zeros(2, 3, 4);
        nan_d.tensors[0].fill(f32::NAN);
        let mut big = ModelParams::zeros(2, 3, 4);
        for t in big.tensors.iter_mut() {
            t.fill(3.0);
        }
        let mut deltas = vec![nan_d, big];
        screen_deltas(&mut deltas, RobustAgg::NormClip { c: 1.0 });
        assert!(
            deltas[0].flat_values().iter().all(|&v| v == 0.0),
            "NaN delta screened to zero"
        );
        let norm = deltas[1]
            .flat_values()
            .iter()
            .map(|&v| f64::from(v) * f64::from(v))
            .sum::<f64>()
            .sqrt();
        assert!((norm - 1.0).abs() < 1e-3, "clipped norm {norm}");
        // `none` is the seed behaviour: NaN propagates untouched.
        let mut untouched = vec![ModelParams::zeros(2, 3, 4)];
        untouched[0].tensors[0].fill(f32::NAN);
        screen_deltas(&mut untouched, RobustAgg::None);
        assert!(untouched[0].tensors[0].data().iter().all(|v| v.is_nan()));
    }

    #[test]
    fn event_order_is_time_then_seq() {
        let mut q: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        for (time, seq) in [(2.0, 0), (1.0, 2), (1.0, 1), (3.0, 3)] {
            q.push(Reverse(Event {
                time,
                seq,
                kind: EventKind::Dropout,
            }));
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|Reverse(e)| e.seq)).collect();
        assert_eq!(order, vec![1, 2, 0, 3], "time asc, seq breaks ties");
    }
}
