//! Per-round training history — the raw series behind Figure 3
//! (accuracy vs round), Figure 4 (accuracy vs communication volume) and
//! Tables 4/6/7.

use crate::eval::metrics::AccuracyReport;
use crate::util::json::Json;

/// Where one round's wall-clock went (`fedmlh run` prints the mean
/// split so slow runs can be attributed to training, encoding or
/// aggregation without a profiler).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundTiming {
    /// Local-training seconds summed over the round's `(client,
    /// sub-model)` items — aggregate compute time, which exceeds the
    /// wall-clock share when the engine runs with `workers > 1`.
    pub train_seconds: f64,
    /// Update-encoding (wire codec) seconds, summed over items.
    pub encode_seconds: f64,
    /// Wall-clock seconds of server-side decode + aggregation.
    pub aggregate_seconds: f64,
}

/// One evaluated synchronization round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundRecord {
    /// 0-based round index.
    pub round: usize,
    pub accuracy: AccuracyReport,
    /// Cumulative communication bytes after this round.
    pub comm_bytes: u64,
    /// This round's *encoded* downlink (broadcast) bytes across all
    /// selected clients × sub-models.
    pub down_bytes: u64,
    /// This round's *encoded* uplink (update) bytes across all selected
    /// clients × sub-models.
    pub up_bytes: u64,
    /// Wall-clock seconds of this round's local training + aggregation.
    pub round_seconds: f64,
    /// Mean local training loss across the round's clients.
    pub mean_loss: f64,
    /// Train / encode / aggregate split of this round.
    pub timing: RoundTiming,
    /// Simulated wall-clock at the end of this round under the
    /// event-driven `--async` simulator (0 for the synchronous loop,
    /// whose clocks are real). This is the x-axis of the
    /// wall-clock-vs-accuracy curves.
    pub sim_seconds: f64,
}

/// The full run history.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct History {
    pub records: Vec<RoundRecord>,
}

impl History {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Record with the best mean top-k accuracy (paper's "best accuracy").
    ///
    /// NaN-last total ordering: a diverged round (NaN loss propagating
    /// into the accuracy report) must never panic the comparator or win
    /// over a real number. If *every* round is NaN one of them is still
    /// returned rather than none, so the run still reports a round.
    pub fn best(&self) -> Option<&RoundRecord> {
        self.records.iter().max_by(|a, b| {
            let (x, y) = (a.accuracy.mean_topk(), b.accuracy.mean_topk());
            match (x.is_nan(), y.is_nan()) {
                (true, true) => std::cmp::Ordering::Equal,
                (true, false) => std::cmp::Ordering::Less,
                (false, true) => std::cmp::Ordering::Greater,
                (false, false) => x.partial_cmp(&y).expect("both non-NaN"),
            }
        })
    }

    /// Mean wall-clock seconds per synchronization round (Table 7).
    pub fn mean_round_seconds(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.round_seconds).sum::<f64>() / self.records.len() as f64
    }

    /// Mean per-round train/encode/aggregate split over the evaluated
    /// rounds (zeros when no round was recorded).
    pub fn mean_timing(&self) -> RoundTiming {
        let mut t = RoundTiming::default();
        if self.records.is_empty() {
            return t;
        }
        for r in &self.records {
            t.train_seconds += r.timing.train_seconds;
            t.encode_seconds += r.timing.encode_seconds;
            t.aggregate_seconds += r.timing.aggregate_seconds;
        }
        let n = self.records.len() as f64;
        t.train_seconds /= n;
        t.encode_seconds /= n;
        t.aggregate_seconds /= n;
        t
    }

    /// CSV with one row per evaluated round (figure regeneration).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,top1,top3,top5,freq1,freq3,freq5,infreq1,infreq3,infreq5,comm_bytes,down_bytes,up_bytes,round_seconds,mean_loss,train_seconds,encode_seconds,aggregate_seconds,sim_seconds\n",
        );
        for r in &self.records {
            let a = &r.accuracy;
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{},{},{},{:.4},{:.6},{:.4},{:.4},{:.4},{:.4}\n",
                r.round,
                a.top1,
                a.top3,
                a.top5,
                a.freq1,
                a.freq3,
                a.freq5,
                a.infreq1,
                a.infreq3,
                a.infreq5,
                r.comm_bytes,
                r.down_bytes,
                r.up_bytes,
                r.round_seconds,
                r.mean_loss,
                r.timing.train_seconds,
                r.timing.encode_seconds,
                r.timing.aggregate_seconds,
                r.sim_seconds
            ));
        }
        out
    }

    /// Parse a history CSV written by [`History::to_csv`] (column
    /// lookup is by header name, so column reordering or future columns
    /// don't break old files). Fuel for the sync-vs-async comparison
    /// figure, which reads two saved run histories back.
    pub fn parse_csv(text: &str) -> anyhow::Result<History> {
        let mut lines = text.lines();
        let header = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty history CSV"))?;
        let cols: Vec<&str> = header.split(',').collect();
        let col = |name: &str| -> anyhow::Result<usize> {
            cols.iter()
                .position(|c| *c == name)
                .ok_or_else(|| anyhow::anyhow!("history CSV is missing column '{name}'"))
        };
        let (c_round, c_top1, c_top3, c_top5) =
            (col("round")?, col("top1")?, col("top3")?, col("top5")?);
        let (c_freq1, c_freq3, c_freq5) = (col("freq1")?, col("freq3")?, col("freq5")?);
        let (c_infreq1, c_infreq3, c_infreq5) =
            (col("infreq1")?, col("infreq3")?, col("infreq5")?);
        let (c_comm, c_down, c_up) = (col("comm_bytes")?, col("down_bytes")?, col("up_bytes")?);
        let (c_secs, c_loss) = (col("round_seconds")?, col("mean_loss")?);
        // Histories written before the async simulator landed have no
        // `sim_seconds` column; the synchronous loop records 0 there
        // anyway, so absent means 0 rather than a hard error.
        let c_sim = cols.iter().position(|c| *c == "sim_seconds");
        let (c_train, c_enc, c_agg) = (
            col("train_seconds")?,
            col("encode_seconds")?,
            col("aggregate_seconds")?,
        );

        let mut history = History::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split(',').collect();
            if fields.len() != cols.len() {
                anyhow::bail!(
                    "history CSV row {} has {} fields, header has {}",
                    i + 2,
                    fields.len(),
                    cols.len()
                );
            }
            let f = |c: usize| -> anyhow::Result<f64> {
                fields[c]
                    .trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow::anyhow!("row {}, column {}: {e}", i + 2, cols[c]))
            };
            let u = |c: usize| -> anyhow::Result<u64> {
                fields[c]
                    .trim()
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("row {}, column {}: {e}", i + 2, cols[c]))
            };
            history.push(RoundRecord {
                round: u(c_round)? as usize,
                accuracy: AccuracyReport {
                    top1: f(c_top1)?,
                    top3: f(c_top3)?,
                    top5: f(c_top5)?,
                    freq1: f(c_freq1)?,
                    freq3: f(c_freq3)?,
                    freq5: f(c_freq5)?,
                    infreq1: f(c_infreq1)?,
                    infreq3: f(c_infreq3)?,
                    infreq5: f(c_infreq5)?,
                    ..Default::default()
                },
                comm_bytes: u(c_comm)?,
                down_bytes: u(c_down)?,
                up_bytes: u(c_up)?,
                round_seconds: f(c_secs)?,
                mean_loss: f(c_loss)?,
                timing: RoundTiming {
                    train_seconds: f(c_train)?,
                    encode_seconds: f(c_enc)?,
                    aggregate_seconds: f(c_agg)?,
                },
                sim_seconds: match c_sim {
                    Some(c) => f(c)?,
                    None => 0.0,
                },
            });
        }
        Ok(history)
    }

    /// JSON series (used by `results/*.json`).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("round", Json::num(r.round as f64)),
                        ("top1", Json::num(r.accuracy.top1)),
                        ("top3", Json::num(r.accuracy.top3)),
                        ("top5", Json::num(r.accuracy.top5)),
                        ("infreq1", Json::num(r.accuracy.infreq1)),
                        ("comm_bytes", Json::num(r.comm_bytes as f64)),
                        ("down_bytes", Json::num(r.down_bytes as f64)),
                        ("up_bytes", Json::num(r.up_bytes as f64)),
                        ("round_seconds", Json::num(r.round_seconds)),
                        ("mean_loss", Json::num(r.mean_loss)),
                        ("train_seconds", Json::num(r.timing.train_seconds)),
                        ("encode_seconds", Json::num(r.timing.encode_seconds)),
                        ("aggregate_seconds", Json::num(r.timing.aggregate_seconds)),
                        ("sim_seconds", Json::num(r.sim_seconds)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, top1: f64, secs: f64) -> RoundRecord {
        RoundRecord {
            round,
            accuracy: AccuracyReport {
                top1,
                top3: top1,
                top5: top1,
                ..Default::default()
            },
            comm_bytes: (round as u64 + 1) * 100,
            down_bytes: 60,
            up_bytes: 40,
            round_seconds: secs,
            mean_loss: 1.0 / (round + 1) as f64,
            timing: RoundTiming {
                train_seconds: secs * 0.6,
                encode_seconds: secs * 0.1,
                aggregate_seconds: secs * 0.3,
            },
            sim_seconds: secs * 2.0,
        }
    }

    #[test]
    fn best_round_by_mean_topk() {
        let mut h = History::new();
        h.push(rec(0, 0.2, 1.0));
        h.push(rec(1, 0.5, 1.0));
        h.push(rec(2, 0.4, 1.0));
        assert_eq!(h.best().unwrap().round, 1);
        assert_eq!(h.len(), 3);
    }

    #[test]
    fn mean_round_seconds() {
        let mut h = History::new();
        h.push(rec(0, 0.1, 2.0));
        h.push(rec(1, 0.1, 4.0));
        assert!((h.mean_round_seconds() - 3.0).abs() < 1e-12);
        assert_eq!(History::new().mean_round_seconds(), 0.0);
    }

    #[test]
    fn mean_timing_averages_the_split() {
        let mut h = History::new();
        h.push(rec(0, 0.1, 2.0));
        h.push(rec(1, 0.1, 4.0));
        let t = h.mean_timing();
        assert!((t.train_seconds - 1.8).abs() < 1e-12);
        assert!((t.encode_seconds - 0.3).abs() < 1e-12);
        assert!((t.aggregate_seconds - 0.9).abs() < 1e-12);
        assert_eq!(History::new().mean_timing(), RoundTiming::default());
    }

    #[test]
    fn csv_carries_the_timing_split() {
        let mut h = History::new();
        h.push(rec(0, 0.25, 1.5));
        let csv = h.to_csv();
        assert!(csv.lines().next().unwrap().ends_with(
            "train_seconds,encode_seconds,aggregate_seconds,sim_seconds"
        ));
        // rec(secs = 1.5): split 0.9/0.15/0.45, simulated clock 3.0.
        assert!(csv
            .lines()
            .nth(1)
            .unwrap()
            .ends_with("0.9000,0.1500,0.4500,3.0000"));
    }

    #[test]
    fn best_survives_nan_rounds() {
        // A diverged round (NaN loss → NaN accuracy) used to panic the
        // partial_cmp().unwrap() comparator; it must sort last instead.
        let mut h = History::new();
        h.push(rec(0, 0.2, 1.0));
        h.push(rec(1, f64::NAN, 1.0));
        h.push(rec(2, 0.4, 1.0));
        assert_eq!(h.best().unwrap().round, 2);

        let mut all_nan = History::new();
        all_nan.push(rec(0, f64::NAN, 1.0));
        all_nan.push(rec(1, f64::NAN, 1.0));
        assert!(all_nan.best().is_some(), "all-NaN history still reports");
    }

    #[test]
    fn csv_carries_per_link_bytes() {
        let mut h = History::new();
        h.push(rec(0, 0.25, 1.5));
        let csv = h.to_csv();
        assert!(
            csv.lines().next().unwrap().contains(",comm_bytes,down_bytes,up_bytes,"),
            "header must carry the per-link byte columns"
        );
        // rec(): comm 100 cumulative, 60 down + 40 up this round.
        assert!(csv.lines().nth(1).unwrap().contains(",100,60,40,"));
        let j = h.to_json().to_string_pretty(0);
        let parsed = Json::parse(&j).unwrap();
        let row = &parsed.as_arr().unwrap()[0];
        assert_eq!(row.expect("down_bytes").unwrap().as_f64().unwrap(), 60.0);
        assert_eq!(row.expect("up_bytes").unwrap().as_f64().unwrap(), 40.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut h = History::new();
        h.push(rec(0, 0.25, 1.5));
        let csv = h.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("round,top1"));
        assert!(lines[1].starts_with("0,0.25"));
    }

    #[test]
    fn csv_parses_back() {
        let mut h = History::new();
        h.push(rec(0, 0.25, 1.5));
        h.push(rec(1, 0.5, 2.0));
        let parsed = History::parse_csv(&h.to_csv()).unwrap();
        assert_eq!(parsed.len(), 2);
        let (a, b) = (&parsed.records[1], &h.records[1]);
        assert_eq!(a.round, b.round);
        assert_eq!(a.accuracy.top1, b.accuracy.top1);
        assert_eq!(a.comm_bytes, b.comm_bytes);
        assert_eq!(a.down_bytes, b.down_bytes);
        assert_eq!(a.sim_seconds, b.sim_seconds);
        assert_eq!(a.timing.train_seconds, b.timing.train_seconds);
        // Malformed input fails loudly, not silently.
        assert!(History::parse_csv("").is_err());
        assert!(History::parse_csv("round,top1\n0").is_err());
        assert!(History::parse_csv("nope\n").is_err());
    }

    #[test]
    fn parses_legacy_csv_without_sim_seconds() {
        // A pre-async-simulator history (exactly what `fedmlh run` wrote
        // before the `sim_seconds` column existed) must still parse,
        // with the simulated clock defaulting to 0.
        let legacy = "round,top1,top3,top5,freq1,freq3,freq5,infreq1,infreq3,infreq5,comm_bytes,down_bytes,up_bytes,round_seconds,mean_loss,train_seconds,encode_seconds,aggregate_seconds\n\
                      0,0.250000,0.300000,0.350000,0.1,0.1,0.1,0.1,0.1,0.1,100,60,40,1.5000,0.900000,0.9000,0.1500,0.4500\n\
                      1,0.400000,0.450000,0.500000,0.2,0.2,0.2,0.2,0.2,0.2,200,60,40,2.0000,0.500000,1.2000,0.2000,0.6000\n";
        let h = History::parse_csv(legacy).unwrap();
        assert_eq!(h.len(), 2);
        assert_eq!(h.records[0].sim_seconds, 0.0);
        assert_eq!(h.records[1].round, 1);
        assert_eq!(h.records[1].comm_bytes, 200);
        assert!((h.records[1].accuracy.top1 - 0.4).abs() < 1e-9);
        // Other columns going missing is still a hard error.
        assert!(History::parse_csv("round,top1\n0,0.5\n").is_err());
    }

    #[test]
    fn json_roundtrips() {
        let mut h = History::new();
        h.push(rec(0, 0.25, 1.5));
        let j = h.to_json();
        let parsed = Json::parse(&j.to_string_pretty(0)).unwrap();
        assert_eq!(
            parsed.as_arr().unwrap()[0]
                .expect("top1")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.25
        );
    }
}
