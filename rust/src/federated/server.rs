//! The synchronization-round loop — paper Algorithm 2, lines 9–20.
//!
//! Per round `t`:
//! 1. sample S of K clients ([`super::sampler`]),
//! 2. compress the globals through the
//!    [`Transport`](super::transport::Transport) downlink: dense, q8 or
//!    q8g broadcast one shared payload per sub-model (with server-side
//!    residual folding when `--error-feedback` is on), while the
//!    per-client delta downlink (`--down-codec topk[:frac]`) ships each
//!    selected client a versioned delta against its own replica (full
//!    dense resync past `--resync-every`); every client trains from the
//!    *decoded* broadcast it personally received,
//! 3. hand the `(client, sub-model)` work items to the
//!    [`RoundEngine`](super::engine::RoundEngine), which runs E local
//!    epochs per item through the [`TrainBackend`] (`DeviceTrain`) —
//!    across `cfg.workers` threads when the backend allows — and
//!    encodes each update through the transport's shared
//!    [`UplinkCompressor`](super::transport::UplinkCompressor) (with
//!    per-`(client, sub-model)` error-feedback accumulators when on),
//! 4. meter both links' *encoded* bytes **per client** (dense-
//!    equivalent tracked alongside) in deterministic item order — under
//!    the delta downlink different clients pay different byte counts in
//!    the same round,
//! 5. decode the updates against the broadcast base *each client*
//!    actually received and aggregate each sub-model uniformly over the
//!    S clients ([`super::aggregate`], line 17),
//! 6. evaluate on the test set (predict per sub-model → scheme decode →
//!    top-k metrics) and early-stop on the mean top-k accuracy. When
//!    nothing reads the verdict before the next round (patience 0, no
//!    snapshots, shareable backend, `--workers > 1`) the evaluation
//!    runs on its own thread, overlapped with the next round's
//!    training — same reports, same history rows, off the round
//!    critical path.
//!
//! The loop is algorithm-agnostic: FedAvg is a [`LabelScheme`] with one
//! sub-model over class labels, FedMLH has R sub-models over bucket
//! labels (see [`crate::algo`]). With `dense` on both links,
//! `--error-feedback off` and `workers = 1` this is bit-identical to
//! the historical inline loop.

use anyhow::{bail, Result};

use crate::algo::LabelScheme;
use crate::config::ExperimentConfig;
use crate::data::dataset::{batch_ranges, Dataset};
use crate::data::stats::LabelStats;
use crate::eval::metrics::{evaluate_scores, AccuracyReport, Evaluator};
use crate::model::params::ModelParams;
use crate::partition::Partition;
use crate::util::rng::derive_seed;

use super::aggregate::{aggregate_robust, Weighting};
use super::backend::TrainBackend;
use super::comm::CommMeter;
use super::early_stop::EarlyStopper;
use super::engine::RoundEngine;
use super::fault::{self, FaultKind};
use super::history::{History, RoundRecord, RoundTiming};
use super::sampler::ClientSampler;
use super::sim::SimStats;
use super::snapshot::{config_fingerprint, RunSnapshot};
use super::transport::Transport;
use super::wire::EncodedUpdate;

/// Everything a finished run reports (inputs to Tables 3–7, Figs 3–5).
#[derive(Debug)]
pub struct RunOutput {
    pub history: History,
    pub comm: CommMeter,
    /// Best-round accuracy (paper's reporting point).
    pub best: AccuracyReport,
    /// 1-based round count to reach the best accuracy (Table 6).
    pub best_round: usize,
    /// Cumulative communication bytes at the best round (Table 4).
    pub comm_to_best: u64,
    /// Rounds actually executed (≤ cfg.rounds under early stopping).
    pub rounds_run: usize,
    /// Per-client model memory: all sub-models (Table 5).
    pub model_bytes: usize,
    pub n_models: usize,
    pub total_seconds: f64,
    /// The trained global sub-models at the end of the run (used by the
    /// determinism tests and by callers that evaluate further).
    pub final_globals: Vec<ModelParams>,
    /// Event-driven simulation statistics; `Some` only for runs through
    /// [`super::sim::run_async`], `None` for the synchronous loop.
    pub sim: Option<SimStats>,
}

/// One round's already-metered history fields, parked while that
/// round's evaluation runs on the overlap thread (see `run`'s
/// `overlap_eval`); [`Self::into_record`] attaches the accuracy report
/// when the thread is reaped. Everything here is frozen at the end of
/// the round it describes, so deferring the push changes no values.
struct PendingRecord {
    round: usize,
    comm_bytes: u64,
    down_bytes: u64,
    up_bytes: u64,
    round_seconds: f64,
    mean_loss: f64,
    timing: RoundTiming,
}

impl PendingRecord {
    fn into_record(self, accuracy: AccuracyReport) -> RoundRecord {
        RoundRecord {
            round: self.round,
            accuracy,
            comm_bytes: self.comm_bytes,
            down_bytes: self.down_bytes,
            up_bytes: self.up_bytes,
            round_seconds: self.round_seconds,
            mean_loss: self.mean_loss,
            timing: self.timing,
            sim_seconds: 0.0,
        }
    }
}

/// Run one federated training experiment.
pub fn run(
    cfg: &ExperimentConfig,
    scheme: &dyn LabelScheme,
    backend: &dyn TrainBackend,
    train: &Dataset,
    test: &Dataset,
    partition: &Partition,
) -> Result<RunOutput> {
    cfg.validate()?;
    let t_start = std::time::Instant::now();
    let n_models = scheme.n_models();
    let out_dim = scheme.out_dim();
    let batch = cfg.preset.batch;

    // Global sub-models (Algorithm 2: independent init per table).
    let mut globals: Vec<ModelParams> = (0..n_models)
        .map(|j| {
            ModelParams::init(
                train.d(),
                cfg.preset.hidden,
                out_dim,
                derive_seed(cfg.seed, 0x1417_0000 + j as u64),
            )
        })
        .collect();
    let model_bytes_each = globals[0].byte_size();

    let sampler = ClientSampler::new(cfg.clients, cfg.clients_per_round, cfg.seed);
    // Compression state for both links lives here for the whole run
    // (error-feedback accumulators, broadcast residual folding, and the
    // delta downlink's per-client base replicas).
    let mut transport = Transport::new(cfg, n_models)?;
    let mut comm = CommMeter::new();
    let mut history = History::new();
    let mut stopper = EarlyStopper::new(cfg.patience);

    // Crash-resume: if the snapshot directory already holds a snapshot
    // for *this* experiment (fingerprint-guarded), restore every piece
    // of cross-round state and continue bitwise from the next round.
    let fingerprint = config_fingerprint(cfg);
    let mut start_round = 0usize;
    if let Some(dir) = cfg.snapshot_dir.as_deref() {
        if let Some(snap) = RunSnapshot::load(dir, fingerprint)? {
            if snap.globals.len() != n_models {
                bail!(
                    "snapshot in {} holds {} sub-models, this run has {n_models}",
                    dir.display(),
                    snap.globals.len()
                );
            }
            for (j, g) in snap.globals.iter().enumerate() {
                let e = &globals[j];
                if (g.d, g.hidden, g.out) != (e.d, e.hidden, e.out) {
                    bail!(
                        "snapshot sub-model {j} has shape ({},{},{}), this run needs \
                         ({},{},{})",
                        g.d,
                        g.hidden,
                        g.out,
                        e.d,
                        e.hidden,
                        e.out
                    );
                }
            }
            globals = snap.globals;
            history = snap.history;
            comm = snap.comm;
            let (best, best_round, since_best, observed) = snap.stopper;
            stopper.restore_parts(best, best_round, since_best, observed);
            transport.restore_state(&snap.uplink_state, &snap.downlink_state)?;
            start_round = snap.next_round;
            crate::log_info!(
                "server: resuming from snapshot at round {start_round} ({} evaluated rounds \
                 restored)",
                history.len()
            );
        }
    }

    // Evaluation machinery (frequent split mirrors the partitioner).
    let train_stats = LabelStats::from_dataset(train);
    let frequent_k = partition.class_owner.len().max(1);
    let test_batches = batch_ranges(test.len(), batch);

    let engine = RoundEngine::new(cfg.workers);
    if cfg.workers > 1 && backend.as_parallel().is_none() {
        crate::log_warn!(
            "server: backend '{}' is single-threaded; --workers {} falls back to sequential",
            backend.name(),
            cfg.workers
        );
    }

    // Run-level instrumentation (purely observational — updated from
    // values the loop already computes, never read back into it).
    let obs = crate::obs::metrics::global();
    let m_rounds = obs.counter("fedmlh_rounds_total", "Synchronous rounds completed.");
    let m_down = obs.counter_with(
        "fedmlh_comm_bytes_total",
        "Encoded bytes moved over the federated links.",
        &[("dir", "down")],
    );
    let m_up = obs.counter_with(
        "fedmlh_comm_bytes_total",
        "Encoded bytes moved over the federated links.",
        &[("dir", "up")],
    );
    let m_round_seconds = obs.histogram(
        "fedmlh_round_seconds",
        "Wall-clock seconds per synchronous round.",
        &[0.01, 0.1, 1.0, 10.0, 60.0, 600.0],
    );
    let m_accuracy = obs.gauge(
        "fedmlh_mean_topk_accuracy",
        "Mean top-k accuracy at the latest evaluation.",
    );

    // Overlapped evaluation: when nothing reads the verdict before the
    // next round starts — early stopping is off (patience 0 never
    // stops), no snapshot captures stopper state mid-run, and the
    // backend is shareable across threads — round t's evaluation runs
    // on its own thread while round t+1 trains, taking eval latency
    // off the round critical path. Each report is computed from a
    // clone of the aggregated globals and joined in round order, so
    // history rows are identical to the inline path.
    let overlap_eval = cfg.patience == 0
        && cfg.snapshot_every == 0
        && cfg.workers > 1
        && backend.as_parallel().is_some();
    let train_stats_ref = &train_stats;
    let test_batches_ref: &[(usize, usize)] = &test_batches;

    let mut rounds_run = start_round;
    std::thread::scope(|eval_scope| -> Result<()> {
        let mut pending: Option<(
            PendingRecord,
            std::thread::ScopedJoinHandle<'_, Result<AccuracyReport>>,
        )> = None;
        'rounds: for round in start_round..cfg.rounds {
            let t_round = std::time::Instant::now();
            let _span_round = crate::obs::trace::wall_span("round", 0)
                .map(|g| g.arg("round", crate::util::json::Json::num(round as f64)));
            let selected = sampler.sample(round);

            // -- injected transient failures (`--inject fail:<p>`): the
            // client trains but its upload never arrives. Fates are a pure
            // function of (seed, round, client) — zero RNG draws at rate 0.
            let population = cfg.client_population() as u64;
            let failed: Vec<bool> = selected
                .iter()
                .map(|&client| {
                    let stream = (round as u64)
                        .wrapping_mul(population)
                        .wrapping_add(client as u64);
                    fault::fail_fate(&cfg.inject, cfg.seed, stream)
                })
                .collect();
            for &lost in &failed {
                if lost {
                    fault::record(FaultKind::Fail);
                }
            }

            // -- downlink (Algorithm 2 line 10): dense/q8/q8g compress each
            // sub-model once and every selected client downloads the same
            // payload; the delta downlink addresses each client separately,
            // against the base replica that client last decoded. Either
            // way, clients train from the *decoded* form, so a lossy
            // broadcast affects training exactly as it would in deployment.
            let bcast = transport.broadcast(round, &selected, &globals)?;

            // -- local training (Algorithm 2 lines 11–15), fanned out over
            // the engine's worker pool; results come back in deterministic
            // (selected order, sub-model) order regardless of worker count.
            let updates = engine.run_round(
                cfg,
                scheme,
                backend,
                transport.uplink(),
                train,
                partition,
                &bcast,
                round,
                &selected,
            )?;

            // -- communication accounting + loss averaging, in item order.
            // Both links are charged their actual *encoded* bytes per
            // client (Table 4 honesty under compression — the dense-
            // equivalent is tracked alongside on each link). Under the
            // delta downlink a resynced client is charged a full model
            // while its neighbors are charged small deltas.
            let down_before = comm.downloaded();
            let up_before = comm.uploaded();
            let mut loss_sum = 0.0f64;
            let mut loss_n = 0usize;
            let mut timing = RoundTiming::default();
            for (slot, per_model) in updates.iter().enumerate() {
                for (j, upd) in per_model.iter().enumerate() {
                    comm.download_encoded(bcast.payload(slot, j).byte_len(), model_bytes_each);
                    timing.train_seconds += upd.stats.seconds;
                    timing.encode_seconds += upd.encode_seconds;
                    if failed[slot] {
                        // The upload never arrived: no uplink bytes, and the
                        // server never learns this client's loss.
                        continue;
                    }
                    comm.upload_encoded(upd.encoded.byte_len(), model_bytes_each);
                    if upd.stats.steps > 0 {
                        loss_sum += upd.stats.mean_loss;
                        loss_n += 1;
                    }
                }
            }
            let down_bytes = comm.downloaded() - down_before;
            let up_bytes = comm.uploaded() - up_before;

            // -- decode + aggregation (line 17), uniform 1/S as in
            // Algorithm 2. Decoding happens against the broadcast base each
            // client actually received (`bcast.global(slot, j)`, which is
            // client-specific under the delta downlink and differs from
            // `globals[j]` whenever the downlink codec is lossy).
            let t_agg = std::time::Instant::now();
            {
                let _span_agg = crate::obs::trace::wall_span("aggregate", 0);
                let inject_payloads =
                    cfg.inject.corrupt > 0.0 || cfg.inject.truncate > 0.0 || cfg.inject.nan > 0.0;
                let n_tensors = globals[0].tensors.len();
                let n_values = globals[0].num_params();
                for j in 0..n_models {
                    let mut decoded: Vec<ModelParams> = Vec::with_capacity(selected.len());
                    let mut sizes: Vec<usize> = Vec::with_capacity(selected.len());
                    for (slot, per_model) in updates.iter().enumerate() {
                        if failed[slot] {
                            continue;
                        }
                        let client = selected[slot];
                        let enc = &per_model[j].encoded;
                        let update = if inject_payloads {
                            let stream = fault::item_stream(
                                round as u64,
                                population,
                                client as u64,
                                n_models as u64,
                                j as u64,
                            );
                            match inject_and_decode(
                                cfg,
                                &transport,
                                bcast.global(slot, j),
                                enc,
                                stream,
                                n_tensors,
                                n_values,
                            )? {
                                Some(m) => m,
                                None => continue, // discarded (bytes already charged)
                            }
                        } else {
                            transport.decode(bcast.global(slot, j), enc)?
                        };
                        decoded.push(update);
                        sizes.push(partition.clients[client].len());
                    }
                    if decoded.is_empty() {
                        // Every contribution was lost or discarded this
                        // round; the previous global survives unchanged.
                        crate::log_warn!(
                            "server: round {round}, sub-model {j}: no usable updates — keeping \
                             previous global"
                        );
                        continue;
                    }
                    let refs: Vec<(&ModelParams, usize)> = decoded
                        .iter()
                        .zip(sizes.iter())
                        .map(|(model, &n)| (model, n))
                        .collect();
                    globals[j] =
                        aggregate_robust(&globals[j], &refs, Weighting::Uniform, cfg.robust)?;
                }
            }
            timing.aggregate_seconds = t_agg.elapsed().as_secs_f64();
            comm.end_round();
            let round_seconds = t_round.elapsed().as_secs_f64();
            rounds_run = round + 1;
            m_rounds.inc();
            m_down.add(down_bytes);
            m_up.add(up_bytes);
            m_round_seconds.observe(round_seconds);

            // -- evaluation. The metered fields are frozen here either
            // way; the accuracy report joins them immediately (inline
            // path) or when the overlap thread is reaped before the
            // next record is pushed.
            let mut stop = false;
            if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
                if let Some((rec, handle)) = pending.take() {
                    let report = handle.join().expect("overlap eval thread panicked")?;
                    m_accuracy.set(report.mean_topk());
                    stopper.observe(rec.round, report.mean_topk());
                    history.push(rec.into_record(report));
                }
                let rec = PendingRecord {
                    round,
                    comm_bytes: comm.total(),
                    down_bytes,
                    up_bytes,
                    round_seconds,
                    mean_loss: if loss_n > 0 { loss_sum / loss_n as f64 } else { 0.0 },
                    timing,
                };
                match (overlap_eval, backend.as_parallel()) {
                    (true, Some(par)) => {
                        // Round t's eval overlaps round t+1's training.
                        // It reads a clone of the aggregated globals, so
                        // the report is bitwise the inline one.
                        let snapshot = globals.clone();
                        let handle = eval_scope.spawn(move || {
                            let _span_eval = crate::obs::trace::wall_span("evaluate", 0);
                            evaluate(
                                scheme,
                                par,
                                &snapshot,
                                test,
                                train_stats_ref,
                                frequent_k,
                                batch,
                                test_batches_ref,
                            )
                        });
                        pending = Some((rec, handle));
                    }
                    _ => {
                        let report = {
                            let _span_eval = crate::obs::trace::wall_span("evaluate", 0);
                            // The otherwise-idle worker budget row-slices
                            // the eval GEMMs (bitwise-safe at any count).
                            let _budget =
                                crate::kernels::parallel::set_kernel_threads(cfg.workers);
                            evaluate(
                                scheme,
                                backend,
                                &globals,
                                test,
                                train_stats_ref,
                                frequent_k,
                                batch,
                                test_batches_ref,
                            )?
                        };
                        m_accuracy.set(report.mean_topk());
                        stop = stopper.observe(round, report.mean_topk());
                        history.push(rec.into_record(report));
                    }
                }
            }

            // -- crash-resume snapshot (`--snapshot-every`), written after
            // evaluation so the stopper's verdict for this round is
            // captured; a kill at any point later resumes from here.
            // (Never concurrent with an overlapped eval: the overlap
            // gate requires `--snapshot-every 0`.)
            if cfg.snapshot_every > 0 && (round + 1) % cfg.snapshot_every == 0 {
                let dir = cfg
                    .snapshot_dir
                    .as_deref()
                    .expect("config validation pairs --snapshot-every with --resume");
                let (uplink_state, downlink_state) = transport.snapshot_state();
                RunSnapshot {
                    next_round: round + 1,
                    globals: globals.clone(),
                    history: history.clone(),
                    comm: comm.clone(),
                    stopper: stopper.snapshot_parts(),
                    uplink_state,
                    downlink_state,
                }
                .save(dir, fingerprint)?;
            }
            if stop {
                break 'rounds;
            }
        }

        // Reap the last round's overlapped evaluation (the loop defers
        // each join until the *next* record is due).
        if let Some((rec, handle)) = pending.take() {
            let report = handle.join().expect("overlap eval thread panicked")?;
            m_accuracy.set(report.mean_topk());
            stopper.observe(rec.round, report.mean_topk());
            history.push(rec.into_record(report));
        }
        Ok(())
    })?;

    let best_rec = *history
        .best()
        .ok_or_else(|| anyhow::anyhow!("no evaluation rounds recorded"))?;
    Ok(RunOutput {
        best: best_rec.accuracy,
        best_round: best_rec.round + 1,
        comm_to_best: best_rec.comm_bytes,
        rounds_run,
        model_bytes: model_bytes_each * n_models,
        n_models,
        total_seconds: t_start.elapsed().as_secs_f64(),
        history,
        comm,
        final_globals: globals,
        sim: None,
    })
}

/// Draw and apply the injected payload fate for one `(round, client,
/// sub-model)` item (`--inject`): corrupt and truncate mutate the
/// *framed* wire bytes so the checksummed decode rejects them — the
/// same path a genuinely damaged payload takes — and the update is
/// discarded (`Ok(None)`); NaN poisons the decoded update (screening it
/// is `--robust-agg`'s job); a clean fate decodes normally.
#[allow(clippy::too_many_arguments)]
fn inject_and_decode(
    cfg: &ExperimentConfig,
    transport: &Transport,
    reference: &ModelParams,
    enc: &EncodedUpdate,
    stream: u64,
    n_tensors: usize,
    n_values: usize,
) -> Result<Option<ModelParams>> {
    let (fate, mut rng) = fault::payload_fate(&cfg.inject, cfg.seed, stream);
    match fate {
        Some(kind @ (FaultKind::Corrupt | FaultKind::Truncate)) => {
            let mut bytes = enc.to_framed_bytes();
            match kind {
                FaultKind::Corrupt => fault::corrupt_bytes(&mut bytes, &mut rng),
                _ => fault::truncate_bytes(&mut bytes, &mut rng),
            }
            let spec = transport.uplink().spec();
            match EncodedUpdate::from_framed_bytes(spec, n_tensors, n_values, &bytes) {
                Ok(ok) => Ok(Some(transport.decode(reference, &ok)?)),
                Err(_) => {
                    fault::record(kind);
                    Ok(None)
                }
            }
        }
        Some(FaultKind::Nan) => {
            let mut m = transport.decode(reference, enc)?;
            fault::poison_nan(&mut m);
            fault::record(FaultKind::Nan);
            Ok(Some(m))
        }
        _ => Ok(Some(transport.decode(reference, enc)?)),
    }
}

/// Full test-set evaluation: predict per sub-model, decode, top-k.
/// Shared with the async simulator ([`super::sim`]), which evaluates on
/// the same grid after each buffered aggregation.
#[allow(clippy::too_many_arguments)]
pub(crate) fn evaluate(
    scheme: &dyn LabelScheme,
    backend: &dyn TrainBackend,
    globals: &[ModelParams],
    test: &Dataset,
    train_stats: &LabelStats,
    frequent_k: usize,
    batch: usize,
    test_batches: &[(usize, usize)],
) -> Result<AccuracyReport> {
    let mut evaluator = Evaluator::new(train_stats, frequent_k);
    // Persistent forward scratch + logit buffers: every test batch is
    // padded to `batch` rows, so one allocation serves the whole sweep.
    let mut scratch = crate::model::mlp::InferScratch::new();
    let mut logits: Vec<Vec<f32>> = globals.iter().map(|g| vec![0.0f32; batch * g.out]).collect();
    for &(start, end) in test_batches {
        let idx: Vec<usize> = (start..end).collect();
        let (x, rows) = test.feature_batch(&idx, batch);
        backend.predict_models_into(globals, &x, batch, &mut scratch, &mut logits)?;
        let scores = scheme.scores(&logits, rows, backend)?;
        evaluate_scores(test, &idx, &scores, &mut evaluator);
    }
    Ok(evaluator.report())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::scheme_for;
    use crate::config::{Algo, ExperimentConfig};
    use crate::data::synth::generate_preset;
    use crate::federated::backend::RustBackend;
    use crate::federated::transport::DownCodec;
    use crate::partition::noniid::{partition as noniid, NonIidOptions};

    fn tiny_run(algo: Algo, rounds: usize) -> RunOutput {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.rounds = rounds;
        cfg.patience = 0;
        cfg.clients = 4;
        cfg.clients_per_round = 2;
        cfg.local_epochs = 1;
        let data = generate_preset(&cfg.preset, cfg.seed);
        let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
        let scheme = scheme_for(&cfg, algo, &data.train);
        let backend = RustBackend::new();
        run(&cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap()
    }

    #[test]
    fn fedavg_learns_on_tiny() {
        let out = tiny_run(Algo::FedAvg, 6);
        assert_eq!(out.rounds_run, 6);
        assert_eq!(out.n_models, 1);
        let first = out.history.records.first().unwrap().accuracy.top1;
        assert!(
            out.best.top1 > first,
            "no improvement: {first} -> {}",
            out.best.top1
        );
        // comm accounting: 2 clients × 2 dirs × model × 6 rounds
        let expect = 2 * 2 * out.model_bytes as u64 * 6;
        assert_eq!(out.comm.total(), expect);
    }

    #[test]
    fn fedmlh_learns_and_uses_r_models() {
        let out = tiny_run(Algo::FedMlh, 6);
        assert_eq!(out.n_models, 2); // tiny preset R=2
        assert!(out.best.top1 > 0.05, "top1 {}", out.best.top1);
        // FedMLH per-round comm is R sub-models each way
        let expect = 2 * 2 * out.model_bytes as u64 * 6;
        assert_eq!(out.comm.total(), expect);
    }

    #[test]
    fn fedmlh_submodel_smaller_than_fedavg() {
        // On the tiny preset (p = 64) the hidden layers dominate, so the
        // R-sub-model *total* can exceed FedAvg — the paper's Table-5
        // win needs extreme p (asserted structurally in model::params
        // and end-to-end by the eurlex+ harness runs). What must hold at
        // any scale: each sub-model is strictly smaller than the full
        // model, because B < p shrinks the only differing layer.
        let a = tiny_run(Algo::FedAvg, 1);
        let m = tiny_run(Algo::FedMlh, 1);
        assert!(
            m.model_bytes / m.n_models < a.model_bytes,
            "sub-model {} >= fedavg {}",
            m.model_bytes / m.n_models,
            a.model_bytes
        );
    }

    #[test]
    fn early_stopping_stops() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.rounds = 50;
        cfg.patience = 2;
        cfg.clients = 2;
        cfg.clients_per_round = 1;
        cfg.local_epochs = 1;
        cfg.lr = 0.0; // no learning → accuracy flat → stop after patience
        let data = generate_preset(&cfg.preset, cfg.seed);
        let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
        let scheme = scheme_for(&cfg, Algo::FedAvg, &data.train);
        let backend = RustBackend::new();
        // lr=0 fails validation; bypass via minimal positive lr
        cfg.lr = 1e-12;
        let out = run(&cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap();
        assert!(out.rounds_run <= 4, "ran {} rounds", out.rounds_run);
    }

    #[test]
    fn q8_downlink_is_metered_and_decomposed_per_round() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.rounds = 3;
        cfg.patience = 0;
        cfg.clients = 4;
        cfg.clients_per_round = 2;
        cfg.local_epochs = 1;
        cfg.down_codec = DownCodec::QuantI8;
        let data = generate_preset(&cfg.preset, cfg.seed);
        let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
        let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
        let backend = RustBackend::new();
        let out =
            run(&cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap();
        // The broadcast is charged its encoded size; dense-equivalent is
        // tracked alongside, so the downlink ratio is reported not guessed.
        assert!(out.comm.downloaded() < out.comm.downloaded_dense_equiv());
        assert!(
            out.comm.download_compression() > 3.5,
            "q8 downlink ratio {}",
            out.comm.download_compression()
        );
        // Per-round columns decompose the cumulative meter exactly.
        let mut cumulative = 0u64;
        for (r, rec) in out.history.records.iter().enumerate() {
            assert!(rec.down_bytes > 0 && rec.up_bytes > 0, "round {r}");
            cumulative += rec.down_bytes + rec.up_bytes;
            assert_eq!(cumulative, out.comm.total_at_round(r), "round {r}");
        }
        // …and a lossy broadcast still learns.
        assert!(out.best.top1 > 0.02, "top1 {}", out.best.top1);
    }

    #[test]
    fn deterministic_runs() {
        let a = tiny_run(Algo::FedMlh, 3);
        let b = tiny_run(Algo::FedMlh, 3);
        assert_eq!(a.best.top1, b.best.top1);
        assert_eq!(a.comm.total(), b.comm.total());
    }

    #[test]
    fn delta_downlink_charges_full_resyncs_and_small_deltas() {
        let mut cfg = ExperimentConfig::preset("tiny").unwrap();
        cfg.rounds = 4;
        cfg.patience = 0;
        cfg.clients = 3;
        cfg.clients_per_round = 3; // full participation: deltas after round 0
        cfg.local_epochs = 1;
        cfg.down_codec = DownCodec::TopK { frac: 0.1 };
        cfg.resync_every = 8;
        let data = generate_preset(&cfg.preset, cfg.seed);
        let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
        let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
        let backend = RustBackend::new();
        let out =
            run(&cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap();
        // Round 0 is all full resyncs (dense + 9-byte header); every
        // later round ships top-k deltas, far below dense.
        let recs = &out.history.records;
        let full_round = (3 * (out.model_bytes + 9 * out.n_models)) as u64;
        assert_eq!(recs[0].down_bytes, full_round);
        for rec in &recs[1..] {
            assert!(
                rec.down_bytes < full_round / 3,
                "round {}: delta bytes {} not < {}",
                rec.round,
                rec.down_bytes,
                full_round / 3
            );
        }
        // The meter's dense-equivalent keeps charging full models, so
        // the measured ratio reflects the delta savings.
        assert!(out.comm.download_compression() > 2.0);
        // …and training still learns through a lossy per-client downlink.
        assert!(out.best.top1 > 0.02, "top1 {}", out.best.top1);
    }

    #[test]
    fn overlapped_eval_matches_inline_history() {
        // workers > 1 + patience 0 + no snapshots + RustBackend flips
        // the overlap gate on; every deterministic history column must
        // be bitwise what the inline (workers = 1) path records.
        let run_with = |workers: usize| {
            let mut cfg = ExperimentConfig::preset("tiny").unwrap();
            cfg.rounds = 4;
            cfg.patience = 0;
            cfg.clients = 4;
            cfg.clients_per_round = 2;
            cfg.local_epochs = 1;
            cfg.workers = workers;
            let data = generate_preset(&cfg.preset, cfg.seed);
            let part = noniid(&data.train, &NonIidOptions::new(cfg.clients), cfg.seed);
            let scheme = scheme_for(&cfg, Algo::FedMlh, &data.train);
            let backend = RustBackend::new();
            run(&cfg, scheme.as_ref(), &backend, &data.train, &data.test, &part).unwrap()
        };
        let inline = run_with(1);
        let overlapped = run_with(2);
        assert_eq!(inline.history.len(), overlapped.history.len());
        for (a, b) in inline
            .history
            .records
            .iter()
            .zip(overlapped.history.records.iter())
        {
            assert_eq!(a.round, b.round);
            assert_eq!(a.accuracy, b.accuracy, "round {}", a.round);
            assert_eq!(
                (a.comm_bytes, a.down_bytes, a.up_bytes),
                (b.comm_bytes, b.down_bytes, b.up_bytes),
                "round {}",
                a.round
            );
            assert_eq!(a.mean_loss.to_bits(), b.mean_loss.to_bits(), "round {}", a.round);
        }
        assert_eq!(inline.best.top1, overlapped.best.top1);
        assert_eq!(inline.best_round, overlapped.best_round);
    }

    #[test]
    fn round_timing_split_is_recorded() {
        let out = tiny_run(Algo::FedMlh, 2);
        for rec in &out.history.records {
            assert!(rec.timing.train_seconds > 0.0, "round {} trained", rec.round);
            assert!(rec.timing.encode_seconds >= 0.0);
            assert!(rec.timing.aggregate_seconds >= 0.0);
            // The split is a decomposition of (most of) the round: no
            // component may exceed total round wall-clock by itself
            // (train/encode are summed over items but workers = 1 here).
            assert!(rec.timing.train_seconds <= rec.round_seconds);
        }
        let mean = out.history.mean_timing();
        assert!(mean.train_seconds > 0.0);
    }
}
