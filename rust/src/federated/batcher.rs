//! Client-side minibatch assembly.
//!
//! A [`ClientBatcher`] walks one client's shard in shuffled order each
//! epoch and materializes `(x, y)` minibatches into reused buffers — the
//! dense multi-hot targets (`[batch, p]` for FedAvg, `[batch, B]` for a
//! FedMLH sub-model, Algorithm 2 line 6) are never stored for the whole
//! shard, only per batch, which keeps FedAvg's `p`-wide targets from
//! blowing up memory at p = 32k.
//!
//! Only **full** batches are emitted (the AOT train step has a fixed
//! batch shape baked in); the per-epoch reshuffle rotates which samples
//! fall into the dropped tail, so in expectation every sample is seen.

use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::hashing::label_hash::LabelHasher;
use crate::util::rng::{derive_seed, Rng};

/// What the training targets are.
#[derive(Clone)]
pub enum Target {
    /// Raw multi-hot class labels (FedAvg).
    Classes,
    /// Bucket labels of hash table `table` (FedMLH sub-model `table`).
    Buckets { hasher: Arc<LabelHasher>, table: usize },
}

impl Target {
    pub fn out_dim(&self, ds: &Dataset) -> usize {
        match self {
            Target::Classes => ds.p(),
            Target::Buckets { hasher, .. } => hasher.b(),
        }
    }
}

/// One emitted minibatch (borrows the batcher's internal buffers).
pub struct Batch<'a> {
    /// Flat `[batch, d]` features.
    pub x: &'a [f32],
    /// Flat `[batch, out]` multi-hot targets.
    pub y: &'a [f32],
}

/// Shuffled full-batch iterator over one client shard.
pub struct ClientBatcher<'a> {
    ds: &'a Dataset,
    /// This client's sample indices (the partition shard), in the
    /// original order — each `reset(epoch)` shuffles a fresh copy so the
    /// same (seed, epoch) always yields the same batch stream.
    base: Vec<usize>,
    /// Working copy walked by the current epoch.
    samples: Vec<usize>,
    target: Target,
    batch: usize,
    out_dim: usize,
    seed: u64,
    // iteration state
    cursor: usize,
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
}

impl<'a> ClientBatcher<'a> {
    pub fn new(
        ds: &'a Dataset,
        samples: &[usize],
        target: Target,
        batch: usize,
        seed: u64,
    ) -> Self {
        let out_dim = target.out_dim(ds);
        ClientBatcher {
            ds,
            base: samples.to_vec(),
            samples: samples.to_vec(),
            target,
            batch,
            out_dim,
            seed,
            cursor: usize::MAX,
            x_buf: vec![0.0; batch * ds.d()],
            y_buf: vec![0.0; batch * out_dim],
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    pub fn d(&self) -> usize {
        self.ds.d()
    }

    /// Full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.samples.len() / self.batch
    }

    /// Start (or restart) an epoch: reshuffle with an epoch-specific seed.
    pub fn reset(&mut self, epoch: usize) {
        let mut rng = Rng::new(derive_seed(self.seed, 0xba7c_0000 + epoch as u64));
        self.samples.copy_from_slice(&self.base);
        rng.shuffle(&mut self.samples);
        self.cursor = 0;
    }

    /// Materialize the next full batch directly into caller-owned
    /// buffers (the scan path: batches are staged into `[S, batch, ·]`
    /// slabs, so writing there directly skips one copy through the
    /// internal buffers). Returns `false` when the epoch is exhausted.
    pub fn next_batch_into(&mut self, x_out: &mut [f32], y_out: &mut [f32]) -> bool {
        assert!(self.cursor != usize::MAX, "call reset(epoch) first");
        if self.cursor + self.batch > self.samples.len() {
            return false;
        }
        let d = self.ds.d();
        debug_assert_eq!(x_out.len(), self.batch * d);
        debug_assert_eq!(y_out.len(), self.batch * self.out_dim);
        let idx = &self.samples[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        for (row, &i) in idx.iter().enumerate() {
            x_out[row * d..(row + 1) * d].copy_from_slice(self.ds.features_of(i));
        }
        match &self.target {
            Target::Classes => {
                y_out.fill(0.0);
                let p = self.ds.p();
                for (row, &i) in idx.iter().enumerate() {
                    for &l in self.ds.labels_of(i) {
                        y_out[row * p + l as usize] = 1.0;
                    }
                }
            }
            Target::Buckets { hasher, table } => {
                let b = hasher.b();
                for (row, &i) in idx.iter().enumerate() {
                    hasher.bucket_labels_table_into(
                        *table,
                        self.ds.labels_of(i),
                        &mut y_out[row * b..(row + 1) * b],
                    );
                }
            }
        }
        true
    }

    /// Next full batch of this epoch, or `None` when exhausted.
    pub fn next_batch(&mut self) -> Option<Batch<'_>> {
        // Route through `next_batch_into` on the internal buffers
        // (temporarily taken to satisfy the borrow checker).
        let mut x = std::mem::take(&mut self.x_buf);
        let mut y = std::mem::take(&mut self.y_buf);
        let ok = self.next_batch_into(&mut x, &mut y);
        self.x_buf = x;
        self.y_buf = y;
        if ok {
            Some(Batch {
                x: &self.x_buf,
                y: &self.y_buf,
            })
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::data::synth::{generate, SynthSpec};

    fn tiny() -> Dataset {
        let mut spec = SynthSpec::from_preset(&by_name("tiny").unwrap());
        spec.n_train = 100;
        generate(&spec, 1).train
    }

    #[test]
    fn emits_full_batches_only() {
        let ds = tiny();
        let samples: Vec<usize> = (0..50).collect();
        let mut b = ClientBatcher::new(&ds, &samples, Target::Classes, 16, 1);
        b.reset(0);
        let mut count = 0;
        while let Some(batch) = b.next_batch() {
            assert_eq!(batch.x.len(), 16 * ds.d());
            assert_eq!(batch.y.len(), 16 * ds.p());
            count += 1;
        }
        assert_eq!(count, 3); // 50 / 16
        assert_eq!(b.batches_per_epoch(), 3);
    }

    #[test]
    fn class_targets_match_labels() {
        let ds = tiny();
        let samples: Vec<usize> = (0..32).collect();
        let mut b = ClientBatcher::new(&ds, &samples, Target::Classes, 32, 7);
        b.reset(0);
        // find the shuffled order by matching features
        let batch = b.next_batch().unwrap();
        let d = ds.d();
        let p = ds.p();
        for row in 0..32 {
            let xrow = &batch.x[row * d..(row + 1) * d];
            let i = (0..32).find(|&i| ds.features_of(i) == xrow).unwrap();
            for c in 0..p {
                let want = ds.labels_of(i).contains(&(c as u32));
                assert_eq!(batch.y[row * p + c] > 0.5, want);
            }
        }
    }

    #[test]
    fn bucket_targets_match_hasher() {
        let ds = tiny();
        let hasher = Arc::new(LabelHasher::new(9, 2, ds.p(), 8));
        let samples: Vec<usize> = (0..16).collect();
        let mut b = ClientBatcher::new(
            &ds,
            &samples,
            Target::Buckets {
                hasher: hasher.clone(),
                table: 1,
            },
            16,
            3,
        );
        assert_eq!(b.out_dim(), 8);
        b.reset(0);
        let batch = b.next_batch().unwrap();
        let d = ds.d();
        for row in 0..16 {
            let xrow = &batch.x[row * d..(row + 1) * d];
            let i = (0..16).find(|&i| ds.features_of(i) == xrow).unwrap();
            let mut want = vec![0.0f32; 8];
            hasher.bucket_labels_table_into(1, ds.labels_of(i), &mut want);
            assert_eq!(&batch.y[row * 8..(row + 1) * 8], &want[..]);
        }
    }

    #[test]
    fn epochs_reshuffle() {
        let ds = tiny();
        let samples: Vec<usize> = (0..64).collect();
        let mut b = ClientBatcher::new(&ds, &samples, Target::Classes, 16, 5);
        b.reset(0);
        let first: Vec<f32> = b.next_batch().unwrap().x.to_vec();
        b.reset(1);
        let second: Vec<f32> = b.next_batch().unwrap().x.to_vec();
        assert_ne!(first, second, "epoch reshuffle changed nothing");
        // same epoch seed → same order
        b.reset(0);
        let again: Vec<f32> = b.next_batch().unwrap().x.to_vec();
        assert_eq!(first, again);
    }
}
