//! Update wire formats — the codec seam between a client's locally
//! trained sub-model and the bytes that actually cross the network.
//!
//! The paper's headline is communication efficiency (Table 4: up to
//! 18.75× less volume than FedAvg), and that accounting is only honest
//! if the meter charges what a deployment would really ship. This
//! module makes the payload explicit: clients encode their update with
//! an [`CodecSpec`]-selected codec, [`super::comm::CommMeter`] charges
//! the *encoded* byte count, and the server decodes before
//! [`super::aggregate::aggregate`]. The default ([`CodecSpec::Dense`])
//! reproduces the seed behavior bit-for-bit: raw `f32` parameters,
//! `4 × num_params` bytes.
//!
//! ## Codecs and their related-work lineage
//!
//! - [`CodecSpec::Dense`] — raw `f32` values, the FedAvg/FedMLH
//!   baseline wire format (McMahan et al., 2017). Lossless.
//! - [`CodecSpec::QuantI8`] — per-tensor symmetric int8 quantization
//!   (`scale = max|v| / 127`), the classic 4× "model compression for
//!   upload" knob; the same role layer-wise pruning plays in FedLP
//!   (Zhu et al., 2023, `Zhuzzq/FedLP`): a client-side lossy encoder
//!   that the server can still aggregate after decoding.
//! - [`CodecSpec::QuantI8Group`] — group-wise int8 (`q8g:<block>`,
//!   default block 64): one scale per `block` consecutive values
//!   instead of per tensor, so a single outlier coordinate no longer
//!   inflates the quantization step of millions of neighbors. Costs
//!   `4 / block` extra bytes per value; the error bound tightens from
//!   per-tensor `scale/2` to per-*block* `scale/2`.
//! - [`CodecSpec::QuantI4Group`] — group-wise *int4* (`q4g:<block>`,
//!   default block 64): the sub-byte sibling of `q8g`. Two quantized
//!   values share one wire byte (low nibble first), levels span
//!   `[-7, 7]` with `scale = max|v| / 7`, and each block keeps its own
//!   scale exactly like `q8g`. Halves the value stream again at the
//!   cost of a 16× coarser step — every bit removed below 8 compounds
//!   with the paper's label-hashing reduction (Table 4's 18.75×).
//! - [`CodecSpec::TopK`] — sparse coordinate updates selected by
//!   largest |local − global| delta, the mechanism behind
//!   category-aware sparse updates in CatFedAvg (arXiv 2011.07229) and
//!   classic top-k gradient sparsification: ship only the coordinates
//!   that moved. Entries carry the *replacement value* for the selected
//!   coordinate (not the difference), so `frac = 1.0` reconstructs the
//!   local model bit-for-bit; unselected coordinates keep the global
//!   value the server already has.
//! - [`CodecSpec::TopKPacked`] — the same selection, but the sorted
//!   index stream is entropy-coded (first index + successive deltas as
//!   LEB128 varints) instead of raw `u32`s. Sorted top-k indices have
//!   small gaps, so the 4-byte index typically shrinks to 1–2 bytes —
//!   roughly 2× on the index stream, ~1.5× on the whole sparse payload.
//!   The codec *is* the format tag (it is shared setup state, like the
//!   model shape), so a `topk` server keeps decoding old payloads
//!   unchanged while `topkv` clients ship the packed layout.
//!
//! These codecs are deliberately *stateless* — one `(global, local)`
//! pair in, bytes out. The cross-round state that fixes compounding
//! sparsification error (client error-feedback accumulators, server
//! residual folding, the per-client delta downlink) lives in
//! [`super::transport`], which drives these codecs as pluggable
//! backends.
//!
//! ## Delta framing
//!
//! [`encode_delta`] / [`apply_delta`] reuse the same codecs to express
//! one model state *against another the receiver already holds* — the
//! per-client delta broadcast ([`super::transport::DeltaDownlink`]) and
//! the delta checkpoint chain (`serve::checkpoint`) are both built on
//! it. The sparse codecs keep their replacement-entry semantics
//! verbatim (entries are selected by `|target − base|` and carry the
//! exact target value, so applying onto the same base is the ordinary
//! [`decode_update`]); the quantized codecs switch to *difference*
//! semantics (quantize `target − base`, receiver adds it back), which
//! shrinks the scales with the step size. [`encode_changed`] is the
//! lossless extreme: every coordinate whose bits differ, exactly.
//!
//! ## Wire layout (little-endian)
//!
//! Both sides already share the model shape (it is broadcast once at
//! setup, Algorithm 2 line 3), so no codec ships shape metadata:
//!
//! - `Dense`:    `num_params × f32`
//! - `QuantI8`:  `n_tensors × f32` scales, then `num_params × i8`
//! - `QuantI8Group`: `u32` scale count, `n_blocks × f32` scales
//!   (tensors chunked into `block`-sized groups, in tensor order), then
//!   `num_params × i8`
//! - `QuantI4Group`: `u32` scale count, `n_blocks × f32` scales (as in
//!   `QuantI8Group`), then `ceil(num_params / 2)` bytes of packed int4
//!   nibbles — value `2i` in the low nibble of byte `i`, value `2i+1`
//!   in the high nibble, two's-complement 4-bit each. An odd value
//!   count leaves the final high nibble as padding, which *must* be
//!   zero (decoders reject nonzero padding, so trailing garbage cannot
//!   hide there).
//! - `TopKDelta`: `u32` entry count, then per entry `u32` flat index +
//!   `f32` value
//! - `TopKPacked`: `u32` entry count, then the sorted index stream as
//!   varints (first index absolute, the rest as gaps ≥ 1), then the
//!   `f32` values in index order
//!
//! [`EncodedUpdate::byte_len`] is defined as `to_bytes().len()` and is
//! what the meter charges — pinned by `tests/wire_roundtrip.rs`.
//!
//! ## Framed payloads (integrity checking)
//!
//! The raw layouts above validate *structure* (lengths, varint bounds)
//! but not *integrity*: a bit flip inside a value region decodes
//! "successfully" into garbage. [`EncodedUpdate::to_framed_bytes`]
//! wraps any payload in a checksummed frame —
//!
//! ```text
//! magic     2 × u8   "FW"
//! codec     u8       codec tag (cross-checked against the expected spec)
//! len       u32      payload byte count
//! payload   len × u8 the raw wire layout above
//! checksum  u64      FNV-1a over every preceding byte
//! ```
//!
//! — and [`EncodedUpdate::from_framed_bytes`] rejects truncated,
//! oversized, codec-mismatched, and bit-flipped frames with a
//! descriptive `Err` before any payload-sized allocation. This is the
//! uplink layer the fault-tolerant server decodes
//! ([`super::fault`]): a corrupt update is discarded and counted, not
//! aggregated and not a panic.

use anyhow::{anyhow, bail, Result};

use crate::model::params::ModelParams;

/// Default group size for [`CodecSpec::QuantI8Group`] (a bare `q8g`).
pub const DEFAULT_Q8G_BLOCK: usize = 64;

/// Default group size for [`CodecSpec::QuantI4Group`] (a bare `q4g`).
/// At block 64 the scale overhead is 4/64 bytes per value, so q4g
/// payloads land at (0.5 + 1/16) / (1 + 1/16) ≈ 0.53× of q8g.
pub const DEFAULT_Q4G_BLOCK: usize = 64;

/// Largest accepted `q8g`/`q4g` block (keeps the wire `u32` block tag
/// exact).
const MAX_Q8G_BLOCK: usize = 1 << 20;

/// Largest magnitude an int4 level can carry (symmetric: `[-7, 7]`).
const Q4_LEVELS: f32 = 7.0;

/// Which codec encodes client→server updates (CLI: `--codec`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodecSpec {
    /// Raw `f32` parameters — the seed wire format, lossless.
    Dense,
    /// Per-tensor symmetric int8 quantization (~4× smaller).
    QuantI8,
    /// Group-wise symmetric int8: one scale per `block` consecutive
    /// values within each tensor (`q8g:<block>`).
    QuantI8Group { block: usize },
    /// Group-wise symmetric int4 (`q4g:<block>`): two values per wire
    /// byte, levels in `[-7, 7]`, one scale per `block` values.
    QuantI4Group { block: usize },
    /// Top-`frac` coordinates by |local − global|, `frac ∈ (0, 1]`.
    TopK { frac: f32 },
    /// Same selection as [`CodecSpec::TopK`], with the sorted index
    /// stream delta+varint coded.
    TopKPacked { frac: f32 },
}

impl CodecSpec {
    /// Parse a CLI name. The sparse codecs take their fraction either
    /// embedded in the name (`topk:0.05`, the [`Self::name`] echo
    /// format) or, for a bare `topk`/`topkv`, from `topk_frac` (the
    /// `--topk-frac` flag). `q8g`/`q4g` take their block size embedded
    /// (`q8g:128`, `q4g:32`) or default to [`DEFAULT_Q8G_BLOCK`] /
    /// [`DEFAULT_Q4G_BLOCK`].
    pub fn parse(name: &str, topk_frac: f32) -> Result<CodecSpec> {
        let (family, embedded) = match name.split_once(':') {
            Some((family, param)) => (family, Some(param)),
            None => (name, None),
        };
        // This closure only *parses*; bounds come from `validate` below.
        let frac_for = |family: &str| -> Result<f32> {
            match embedded {
                Some(s) => s
                    .parse::<f32>()
                    .map_err(|e| anyhow!("bad {family} fraction '{s}': {e}")),
                None => Ok(topk_frac),
            }
        };
        let spec = match family {
            "dense" | "q8" | "quant" if embedded.is_some() => {
                bail!("codec '{family}' does not take a parameter")
            }
            "dense" => CodecSpec::Dense,
            "q8" | "quant" => CodecSpec::QuantI8,
            "q8g" => {
                let block = match embedded {
                    Some(s) => s
                        .parse::<usize>()
                        .map_err(|e| anyhow!("bad q8g block '{s}': {e}"))?,
                    None => DEFAULT_Q8G_BLOCK,
                };
                CodecSpec::QuantI8Group { block }
            }
            "q4g" => {
                let block = match embedded {
                    Some(s) => s
                        .parse::<usize>()
                        .map_err(|e| anyhow!("bad q4g block '{s}': {e}"))?,
                    None => DEFAULT_Q4G_BLOCK,
                };
                CodecSpec::QuantI4Group { block }
            }
            "topk" => CodecSpec::TopK { frac: frac_for("topk")? },
            "topkv" => CodecSpec::TopKPacked { frac: frac_for("topkv")? },
            other => bail!(
                "unknown codec '{other}' \
                 (expected dense|q8|q8g[:block]|q4g[:block]|topk[:frac]|topkv[:frac])"
            ),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Bounds-check the spec's parameters — the single source for CLI
    /// parsing, `ExperimentConfig::validate` (both links) and the
    /// encoders: sparse fractions in `(0, 1]`, q8g/q4g blocks in
    /// `1..=`[`MAX_Q8G_BLOCK`].
    pub fn validate(&self) -> Result<()> {
        match *self {
            CodecSpec::Dense | CodecSpec::QuantI8 => Ok(()),
            CodecSpec::QuantI8Group { block } => {
                if block == 0 || block > MAX_Q8G_BLOCK {
                    bail!("q8g block must be in 1..={MAX_Q8G_BLOCK}, got {block}");
                }
                Ok(())
            }
            CodecSpec::QuantI4Group { block } => {
                if block == 0 || block > MAX_Q8G_BLOCK {
                    bail!("q4g block must be in 1..={MAX_Q8G_BLOCK}, got {block}");
                }
                Ok(())
            }
            CodecSpec::TopK { frac } | CodecSpec::TopKPacked { frac } => {
                if !(frac > 0.0 && frac <= 1.0) {
                    bail!("topk fraction must be in (0, 1], got {frac}");
                }
                Ok(())
            }
        }
    }

    /// Wire tag identifying the codec family inside a framed payload
    /// ([`EncodedUpdate::to_framed_bytes`]).
    pub fn tag(&self) -> u8 {
        match self {
            CodecSpec::Dense => 0,
            CodecSpec::QuantI8 => 1,
            CodecSpec::QuantI8Group { .. } => 2,
            CodecSpec::TopK { .. } => 3,
            CodecSpec::TopKPacked { .. } => 4,
            CodecSpec::QuantI4Group { .. } => 5,
        }
    }

    /// Canonical spec string: `dense`, `q8`, `q8g:<block>`,
    /// `q4g:<block>`, `topk:<frac>`, `topkv:<frac>`. Every output re-parses to an
    /// equal spec through [`Self::parse`] (regardless of the
    /// `topk_frac` argument), so config echoes round-trip losslessly —
    /// pinned by `spec_string_roundtrips_every_variant`.
    pub fn name(&self) -> String {
        match self {
            CodecSpec::Dense => "dense".to_string(),
            CodecSpec::QuantI8 => "q8".to_string(),
            CodecSpec::QuantI8Group { block } => format!("q8g:{block}"),
            CodecSpec::QuantI4Group { block } => format!("q4g:{block}"),
            CodecSpec::TopK { frac } => format!("topk:{frac}"),
            CodecSpec::TopKPacked { frac } => format!("topkv:{frac}"),
        }
    }
}

// -- LEB128 varints for the packed index stream -------------------------

fn varint_len(mut v: u32) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

fn push_varint(out: &mut Vec<u8>, mut v: u32) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    let mut v: u32 = 0;
    let mut shift = 0u32;
    loop {
        let Some(&b) = bytes.get(*pos) else {
            bail!("varint runs past the end of the payload");
        };
        *pos += 1;
        if shift == 28 && (b & 0x7f) > 0x0f {
            bail!("varint overflows u32");
        }
        v |= ((b & 0x7f) as u32) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 28 {
            bail!("varint longer than 5 bytes");
        }
    }
}

/// The delta stream of sorted `entries`: first index absolute, then
/// successive gaps. The single source of the gap walk — both
/// [`EncodedUpdate::byte_len`] and the `TopKPacked` serializer consume
/// it, so the `byte_len() == to_bytes().len()` invariant CommMeter
/// billing relies on cannot drift.
fn index_gaps(entries: &[(u32, f32)]) -> impl Iterator<Item = u32> + '_ {
    let mut prev = 0u32;
    entries.iter().enumerate().map(move |(slot, &(idx, _))| {
        let gap = if slot == 0 { idx } else { idx - prev };
        prev = idx;
        gap
    })
}

/// Encoded size of the delta+varint index stream of sorted `entries`.
fn packed_index_len(entries: &[(u32, f32)]) -> usize {
    index_gaps(entries).map(varint_len).sum()
}

// -- int4 nibble packing for the q4g value stream -----------------------

/// Pack int4 levels (each in `[-8, 7]`; the encoder only emits
/// `[-7, 7]`) two per byte: value `2i` in the low nibble, `2i+1` in the
/// high nibble, two's-complement 4-bit. An odd count leaves the final
/// high nibble zero.
fn pack_nibbles(out: &mut Vec<u8>, values: &[i8]) {
    let mut it = values.chunks_exact(2);
    for pair in it.by_ref() {
        out.push((pair[0] as u8 & 0x0f) | ((pair[1] as u8 & 0x0f) << 4));
    }
    if let [last] = it.remainder() {
        out.push(*last as u8 & 0x0f);
    }
}

/// Sign-extend one 4-bit two's-complement nibble.
fn unpack_nibble(nib: u8) -> i8 {
    (((nib & 0x0f) << 4) as i8) >> 4
}

/// One encoded client update, ready to meter and ship.
#[derive(Clone, Debug, PartialEq)]
pub enum EncodedUpdate {
    /// Flat `f32` values in [`ModelParams::flat_values`] order.
    Dense { values: Vec<f32> },
    /// One scale per tensor plus `num_params` quantized values.
    QuantI8 { scales: Vec<f32>, values: Vec<i8> },
    /// One scale per `block`-sized group within each tensor plus
    /// `num_params` quantized values.
    QuantI8Group {
        block: u32,
        scales: Vec<f32>,
        values: Vec<i8>,
    },
    /// Group-wise int4: like [`EncodedUpdate::QuantI8Group`] but each
    /// value is a level in `[-7, 7]` and two values share one wire
    /// byte. Kept *unpacked* in memory (one `i8` per value) so decode
    /// and the tests index values directly; packing happens only in
    /// [`Self::to_bytes`] / [`Self::byte_len`].
    QuantI4Group {
        block: u32,
        scales: Vec<f32>,
        values: Vec<i8>,
    },
    /// Sorted `(flat index, replacement value)` pairs.
    TopKDelta { entries: Vec<(u32, f32)> },
    /// Sorted `(flat index, replacement value)` pairs, index stream
    /// delta+varint coded on the wire.
    TopKPacked { entries: Vec<(u32, f32)> },
}

impl EncodedUpdate {
    /// Exact payload size in bytes; equals `self.to_bytes().len()` and
    /// is the number [`super::comm::CommMeter`] is charged.
    pub fn byte_len(&self) -> usize {
        match self {
            EncodedUpdate::Dense { values } => 4 * values.len(),
            EncodedUpdate::QuantI8 { scales, values } => 4 * scales.len() + values.len(),
            EncodedUpdate::QuantI8Group { scales, values, .. } => {
                4 + 4 * scales.len() + values.len()
            }
            // Ceil-div on the nibble stream: an odd value count still
            // occupies its final (zero-padded) byte on the wire, and
            // the CommMeter is charged exactly that.
            EncodedUpdate::QuantI4Group { scales, values, .. } => {
                4 + 4 * scales.len() + values.len().div_ceil(2)
            }
            EncodedUpdate::TopKDelta { entries } => 4 + 8 * entries.len(),
            EncodedUpdate::TopKPacked { entries } => {
                4 + packed_index_len(entries) + 4 * entries.len()
            }
        }
    }

    pub fn codec_name(&self) -> &'static str {
        match self {
            EncodedUpdate::Dense { .. } => "dense",
            EncodedUpdate::QuantI8 { .. } => "q8",
            EncodedUpdate::QuantI8Group { .. } => "q8g",
            EncodedUpdate::QuantI4Group { .. } => "q4g",
            EncodedUpdate::TopKDelta { .. } => "topk",
            EncodedUpdate::TopKPacked { .. } => "topkv",
        }
    }

    /// Serialize to the little-endian wire layout (module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            EncodedUpdate::Dense { values } => {
                let mut out = Vec::with_capacity(4 * values.len());
                for v in values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
            EncodedUpdate::QuantI8 { scales, values } => {
                let mut out = Vec::with_capacity(4 * scales.len() + values.len());
                for s in scales {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                for &q in values {
                    out.push(q as u8);
                }
                out
            }
            EncodedUpdate::QuantI8Group { scales, values, .. } => {
                let mut out = Vec::with_capacity(self.byte_len());
                out.extend_from_slice(&(scales.len() as u32).to_le_bytes());
                for s in scales {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                for &q in values {
                    out.push(q as u8);
                }
                out
            }
            EncodedUpdate::QuantI4Group { scales, values, .. } => {
                let mut out = Vec::with_capacity(self.byte_len());
                out.extend_from_slice(&(scales.len() as u32).to_le_bytes());
                for s in scales {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                pack_nibbles(&mut out, values);
                out
            }
            EncodedUpdate::TopKDelta { entries } => {
                let mut out = Vec::with_capacity(4 + 8 * entries.len());
                out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                for &(i, v) in entries {
                    out.extend_from_slice(&i.to_le_bytes());
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
            EncodedUpdate::TopKPacked { entries } => {
                let mut out = Vec::with_capacity(self.byte_len());
                out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                for gap in index_gaps(entries) {
                    push_varint(&mut out, gap);
                }
                for &(_, v) in entries {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
        }
    }

    /// Parse the wire layout back. `n_tensors`/`n_values` come from the
    /// shared model shape (they are not on the wire).
    pub fn from_bytes(
        spec: CodecSpec,
        n_tensors: usize,
        n_values: usize,
        bytes: &[u8],
    ) -> Result<EncodedUpdate> {
        fn f32_at(bytes: &[u8], off: usize) -> f32 {
            f32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
        }
        fn u32_at(bytes: &[u8], off: usize) -> u32 {
            u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
        }
        match spec {
            CodecSpec::Dense => {
                if bytes.len() != 4 * n_values {
                    bail!(
                        "dense payload is {} bytes, expected {}",
                        bytes.len(),
                        4 * n_values
                    );
                }
                let values = (0..n_values).map(|i| f32_at(bytes, 4 * i)).collect();
                Ok(EncodedUpdate::Dense { values })
            }
            CodecSpec::QuantI8 => {
                let want = 4 * n_tensors + n_values;
                if bytes.len() != want {
                    bail!("q8 payload is {} bytes, expected {want}", bytes.len());
                }
                let scales = (0..n_tensors).map(|i| f32_at(bytes, 4 * i)).collect();
                let values = bytes[4 * n_tensors..].iter().map(|&b| b as i8).collect();
                Ok(EncodedUpdate::QuantI8 { scales, values })
            }
            CodecSpec::QuantI8Group { block } => {
                if bytes.len() < 4 {
                    bail!("q8g payload is {} bytes, expected at least 4", bytes.len());
                }
                let n_scales = u32_at(bytes, 0) as usize;
                let want = 4 + 4 * n_scales + n_values;
                if bytes.len() != want {
                    bail!(
                        "q8g payload is {} bytes, header says {want} \
                         ({n_scales} scales, {n_values} values)",
                        bytes.len()
                    );
                }
                let scales = (0..n_scales).map(|i| f32_at(bytes, 4 + 4 * i)).collect();
                let values = bytes[4 + 4 * n_scales..].iter().map(|&b| b as i8).collect();
                Ok(EncodedUpdate::QuantI8Group {
                    block: block as u32,
                    scales,
                    values,
                })
            }
            CodecSpec::QuantI4Group { block } => {
                if bytes.len() < 4 {
                    bail!("q4g payload is {} bytes, expected at least 4", bytes.len());
                }
                let n_scales = u32_at(bytes, 0) as usize;
                let want = 4 + 4 * n_scales + n_values.div_ceil(2);
                if bytes.len() != want {
                    bail!(
                        "q4g payload is {} bytes, header says {want} \
                         ({n_scales} scales, {n_values} packed values)",
                        bytes.len()
                    );
                }
                let scales = (0..n_scales).map(|i| f32_at(bytes, 4 + 4 * i)).collect();
                let packed = &bytes[4 + 4 * n_scales..];
                let mut values = Vec::with_capacity(n_values);
                for (i, &b) in packed.iter().enumerate() {
                    values.push(unpack_nibble(b));
                    if 2 * i + 1 < n_values {
                        values.push(unpack_nibble(b >> 4));
                    } else if b >> 4 != 0 {
                        // Odd value count: the final high nibble is
                        // padding and must be zero — a nonzero nibble
                        // here is corruption, not data.
                        bail!("q4g payload has nonzero padding in its final nibble");
                    }
                }
                Ok(EncodedUpdate::QuantI4Group {
                    block: block as u32,
                    scales,
                    values,
                })
            }
            CodecSpec::TopK { .. } => {
                if bytes.len() < 4 {
                    bail!("topk payload is {} bytes, expected at least 4", bytes.len());
                }
                let k = u32_at(bytes, 0) as usize;
                if bytes.len() != 4 + 8 * k {
                    bail!(
                        "topk payload is {} bytes, header says {}",
                        bytes.len(),
                        4 + 8 * k
                    );
                }
                let entries = (0..k)
                    .map(|e| (u32_at(bytes, 4 + 8 * e), f32_at(bytes, 8 + 8 * e)))
                    .collect();
                Ok(EncodedUpdate::TopKDelta { entries })
            }
            CodecSpec::TopKPacked { .. } => {
                if bytes.len() < 4 {
                    bail!("topkv payload is {} bytes, expected at least 4", bytes.len());
                }
                let k = u32_at(bytes, 0) as usize;
                let mut pos = 4usize;
                // Cap the pre-allocation by the payload size: a corrupt
                // count fails in the varint loop, not in the allocator.
                let mut indices = Vec::with_capacity(k.min(bytes.len()));
                let mut prev = 0u32;
                for slot in 0..k {
                    let gap = read_varint(bytes, &mut pos)?;
                    let idx = if slot == 0 {
                        gap
                    } else {
                        if gap == 0 {
                            bail!("topkv index stream is not strictly increasing");
                        }
                        prev.checked_add(gap)
                            .ok_or_else(|| anyhow!("topkv index overflows u32"))?
                    };
                    indices.push(idx);
                    prev = idx;
                }
                if bytes.len() != pos + 4 * k {
                    bail!(
                        "topkv payload is {} bytes, header says {}",
                        bytes.len(),
                        pos + 4 * k
                    );
                }
                let entries = indices
                    .into_iter()
                    .enumerate()
                    .map(|(e, idx)| (idx, f32_at(bytes, pos + 4 * e)))
                    .collect();
                Ok(EncodedUpdate::TopKPacked { entries })
            }
        }
    }
}

/// Magic bytes opening a framed payload.
pub const FRAME_MAGIC: [u8; 2] = *b"FW";

/// Fixed framing cost: magic (2) + codec tag (1) + length (4) +
/// trailing checksum (8).
pub const FRAME_OVERHEAD: usize = 2 + 1 + 4 + 8;

/// FNV-1a 64-bit — the frame and snapshot corruption check (fast, not
/// cryptographic; a single flipped byte always changes the digest).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl EncodedUpdate {
    /// Codec tag of this payload's family (matches
    /// [`CodecSpec::tag`] for the spec that produced it).
    fn family_tag(&self) -> u8 {
        match self {
            EncodedUpdate::Dense { .. } => 0,
            EncodedUpdate::QuantI8 { .. } => 1,
            EncodedUpdate::QuantI8Group { .. } => 2,
            EncodedUpdate::TopKDelta { .. } => 3,
            EncodedUpdate::TopKPacked { .. } => 4,
            EncodedUpdate::QuantI4Group { .. } => 5,
        }
    }

    /// Size of [`Self::to_framed_bytes`]'s output.
    pub fn framed_len(&self) -> usize {
        self.byte_len() + FRAME_OVERHEAD
    }

    /// Serialize with the checksummed frame (module docs §Framed
    /// payloads) — the integrity-checked form the fault-tolerant
    /// uplink ships.
    pub fn to_framed_bytes(&self) -> Vec<u8> {
        let payload = self.to_bytes();
        let mut out = Vec::with_capacity(payload.len() + FRAME_OVERHEAD);
        out.extend_from_slice(&FRAME_MAGIC);
        out.push(self.family_tag());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse a framed payload back, rejecting any frame that is
    /// truncated, oversized, carries the wrong codec tag, or fails its
    /// checksum — every failure is a descriptive `Err`, never a panic,
    /// and the declared length is validated against the buffer before
    /// anything payload-sized is allocated.
    pub fn from_framed_bytes(
        spec: CodecSpec,
        n_tensors: usize,
        n_values: usize,
        bytes: &[u8],
    ) -> Result<EncodedUpdate> {
        if bytes.len() < FRAME_OVERHEAD {
            bail!(
                "framed payload is {} bytes, smaller than the {FRAME_OVERHEAD}-byte frame",
                bytes.len()
            );
        }
        if bytes[..2] != FRAME_MAGIC {
            bail!("framed payload has bad magic (not an update frame)");
        }
        if bytes[2] != spec.tag() {
            bail!(
                "framed payload carries codec tag {} but the link expects {} ({})",
                bytes[2],
                spec.tag(),
                spec.name()
            );
        }
        let declared = u32::from_le_bytes([bytes[3], bytes[4], bytes[5], bytes[6]]) as usize;
        // Exact-length check first: an oversized declared length (or a
        // truncated buffer) is rejected here, before the checksum walk
        // and before `from_bytes` sizes any allocation off `declared`.
        if bytes.len() != FRAME_OVERHEAD + declared {
            bail!(
                "framed payload is {} bytes, frame header declares {}",
                bytes.len(),
                FRAME_OVERHEAD + declared
            );
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        if fnv1a64(body) != want {
            bail!("framed payload checksum mismatch (corrupt or truncated update)");
        }
        EncodedUpdate::from_bytes(spec, n_tensors, n_values, &body[7..])
    }
}

/// Encode a client's trained sub-model against the global it downloaded.
pub fn encode_update(
    spec: CodecSpec,
    global: &ModelParams,
    local: &ModelParams,
) -> Result<EncodedUpdate> {
    if (global.d, global.hidden, global.out) != (local.d, local.hidden, local.out) {
        bail!(
            "encode shape mismatch: global ({},{},{}) vs local ({},{},{})",
            global.d,
            global.hidden,
            global.out,
            local.d,
            local.hidden,
            local.out
        );
    }
    match spec {
        CodecSpec::Dense => Ok(EncodedUpdate::Dense {
            values: local.flat_values(),
        }),
        CodecSpec::QuantI8 => {
            let mut scales = Vec::with_capacity(local.tensors.len());
            let mut values = Vec::with_capacity(local.num_params());
            for t in &local.tensors {
                let mut max_abs = 0.0f32;
                let mut finite = true;
                for &v in t.data() {
                    finite &= v.is_finite();
                    max_abs = max_abs.max(v.abs());
                }
                if !finite {
                    // Silently quantizing a diverged model would zero or
                    // NaN-poison the whole tensor (f32::max skips NaN, and
                    // `as i8` saturate-casts NaN to 0); fail loudly so q8
                    // runs surface divergence the way dense runs do.
                    bail!("q8 encode: non-finite parameter values in update");
                }
                let scale = max_abs / 127.0;
                scales.push(scale);
                if scale == 0.0 {
                    values.extend(std::iter::repeat(0i8).take(t.len()));
                } else {
                    for &v in t.data() {
                        values.push((v / scale).round().clamp(-127.0, 127.0) as i8);
                    }
                }
            }
            Ok(EncodedUpdate::QuantI8 { scales, values })
        }
        CodecSpec::QuantI8Group { block } => {
            spec.validate()?;
            let mut scales = Vec::new();
            let mut values = Vec::with_capacity(local.num_params());
            for t in &local.tensors {
                for chunk in t.data().chunks(block) {
                    let mut max_abs = 0.0f32;
                    let mut finite = true;
                    for &v in chunk {
                        finite &= v.is_finite();
                        max_abs = max_abs.max(v.abs());
                    }
                    if !finite {
                        // Same rationale as q8: fail loudly instead of
                        // silently zeroing/poisoning a diverged block.
                        bail!("q8g encode: non-finite parameter values in update");
                    }
                    let scale = max_abs / 127.0;
                    scales.push(scale);
                    if scale == 0.0 {
                        values.extend(std::iter::repeat(0i8).take(chunk.len()));
                    } else {
                        for &v in chunk {
                            values.push((v / scale).round().clamp(-127.0, 127.0) as i8);
                        }
                    }
                }
            }
            Ok(EncodedUpdate::QuantI8Group {
                block: block as u32,
                scales,
                values,
            })
        }
        CodecSpec::QuantI4Group { block } => {
            spec.validate()?;
            let mut scales = Vec::new();
            let mut values = Vec::with_capacity(local.num_params());
            for t in &local.tensors {
                for chunk in t.data().chunks(block) {
                    let mut max_abs = 0.0f32;
                    let mut finite = true;
                    for &v in chunk {
                        finite &= v.is_finite();
                        max_abs = max_abs.max(v.abs());
                    }
                    if !finite {
                        // Same rationale as q8/q8g: fail loudly instead
                        // of silently zeroing/poisoning a diverged block.
                        bail!("q4g encode: non-finite parameter values in update");
                    }
                    let scale = max_abs / Q4_LEVELS;
                    scales.push(scale);
                    if scale == 0.0 {
                        values.extend(std::iter::repeat(0i8).take(chunk.len()));
                    } else {
                        for &v in chunk {
                            values.push((v / scale).round().clamp(-Q4_LEVELS, Q4_LEVELS) as i8);
                        }
                    }
                }
            }
            Ok(EncodedUpdate::QuantI4Group {
                block: block as u32,
                scales,
                values,
            })
        }
        CodecSpec::TopK { frac } => Ok(EncodedUpdate::TopKDelta {
            entries: select_topk_entries(global, local, frac)?,
        }),
        CodecSpec::TopKPacked { frac } => Ok(EncodedUpdate::TopKPacked {
            entries: select_topk_entries(global, local, frac)?,
        }),
    }
}

/// Deterministic top-k selection shared by the sparse codecs: largest
/// |local − global| first, index as the tie-break. total_cmp gives a
/// total order, so the kept set is unique and the parallel engine
/// reproduces the sequential choice exactly; select_nth keeps this O(n)
/// instead of a full sort over multi-million-param models. Returned
/// entries are sorted by index (ascending).
fn select_topk_entries(
    global: &ModelParams,
    local: &ModelParams,
    frac: f32,
) -> Result<Vec<(u32, f32)>> {
    if !(frac > 0.0 && frac <= 1.0) {
        bail!("topk fraction must be in (0, 1], got {frac}");
    }
    let g = global.flat_values();
    let l = local.flat_values();
    let n = l.len();
    let k = ((n as f64 * frac as f64).ceil() as usize).clamp(1, n);
    let by_delta_desc = |a: &u32, b: &u32| {
        let da = (l[*a as usize] - g[*a as usize]).abs();
        let db = (l[*b as usize] - g[*b as usize]).abs();
        db.total_cmp(&da).then(a.cmp(b))
    };
    let mut order: Vec<u32> = (0..n as u32).collect();
    if k < n {
        order.select_nth_unstable_by(k - 1, by_delta_desc);
    }
    let mut keep = order[..k].to_vec();
    keep.sort_unstable();
    Ok(keep.into_iter().map(|i| (i, l[i as usize])).collect())
}

/// Decode an update back into full parameters, against the same global
/// the client encoded from.
pub fn decode_update(global: &ModelParams, enc: &EncodedUpdate) -> Result<ModelParams> {
    let n = global.num_params();
    let mut out = ModelParams::zeros(global.d, global.hidden, global.out);
    match enc {
        EncodedUpdate::Dense { values } => {
            out.set_from_flat(values)?;
        }
        EncodedUpdate::QuantI8 { scales, values } => {
            if scales.len() != out.tensors.len() {
                bail!(
                    "q8 update has {} scales, model has {} tensors",
                    scales.len(),
                    out.tensors.len()
                );
            }
            if values.len() != n {
                bail!("q8 update has {} values, model has {n}", values.len());
            }
            let mut off = 0;
            for (t, &scale) in out.tensors.iter_mut().zip(scales.iter()) {
                let len = t.len();
                let src = &values[off..off + len];
                for (dst, &q) in t.data_mut().iter_mut().zip(src.iter()) {
                    *dst = q as f32 * scale;
                }
                off += len;
            }
        }
        EncodedUpdate::QuantI8Group { block, scales, values }
        | EncodedUpdate::QuantI4Group { block, scales, values } => {
            let name = enc.codec_name();
            let block = *block as usize;
            if block == 0 {
                bail!("{name} update has a zero block size");
            }
            let want_scales: usize = out.tensors.iter().map(|t| t.len().div_ceil(block)).sum();
            if scales.len() != want_scales {
                bail!(
                    "{name} update has {} scales, model with block {block} needs {want_scales}",
                    scales.len()
                );
            }
            if values.len() != n {
                bail!("{name} update has {} values, model has {n}", values.len());
            }
            let mut off = 0usize;
            let mut si = 0usize;
            for t in out.tensors.iter_mut() {
                let len = t.len();
                let src = &values[off..off + len];
                let chunks = t.data_mut().chunks_mut(block).zip(src.chunks(block));
                for (dst_chunk, src_chunk) in chunks {
                    let scale = scales[si];
                    si += 1;
                    for (dst, &q) in dst_chunk.iter_mut().zip(src_chunk.iter()) {
                        *dst = q as f32 * scale;
                    }
                }
                off += len;
            }
        }
        EncodedUpdate::TopKDelta { entries } | EncodedUpdate::TopKPacked { entries } => {
            let mut vals = global.flat_values();
            for &(i, v) in entries {
                let i = i as usize;
                if i >= n {
                    bail!("topk update index {i} out of range (model has {n} params)");
                }
                vals[i] = v;
            }
            out.set_from_flat(&vals)?;
        }
    }
    Ok(out)
}

fn check_delta_shapes(base: &ModelParams, target: &ModelParams) -> Result<()> {
    if (base.d, base.hidden, base.out) != (target.d, target.hidden, target.out) {
        bail!(
            "delta shape mismatch: base ({},{},{}) vs target ({},{},{})",
            base.d,
            base.hidden,
            base.out,
            target.d,
            target.hidden,
            target.out
        );
    }
    Ok(())
}

/// Encode `target` as a delta against a `base` the receiver already
/// holds (module docs §Delta framing). The sparse codecs reuse their
/// replacement-entry encoding verbatim; the quantized codecs encode the
/// *difference* `target − base` so their scales track the step size;
/// `dense` ships the full target (a lossless "delta").
pub fn encode_delta(
    spec: CodecSpec,
    base: &ModelParams,
    target: &ModelParams,
) -> Result<EncodedUpdate> {
    match spec {
        CodecSpec::Dense | CodecSpec::TopK { .. } | CodecSpec::TopKPacked { .. } => {
            encode_update(spec, base, target)
        }
        CodecSpec::QuantI8 | CodecSpec::QuantI8Group { .. } | CodecSpec::QuantI4Group { .. } => {
            check_delta_shapes(base, target)?;
            let bv = base.flat_values();
            let tv = target.flat_values();
            let vals: Vec<f32> = tv.iter().zip(bv.iter()).map(|(t, b)| *t - *b).collect();
            let mut diff = ModelParams::zeros(base.d, base.hidden, base.out);
            diff.set_from_flat(&vals)?;
            encode_update(spec, base, &diff)
        }
    }
}

/// Apply a delta produced by [`encode_delta`] onto the same `base`,
/// reconstructing the receiver's view of the target.
pub fn apply_delta(base: &ModelParams, enc: &EncodedUpdate) -> Result<ModelParams> {
    match enc {
        // Replacement / full-value payloads decode directly against the
        // base (unselected coordinates keep the base value).
        EncodedUpdate::Dense { .. }
        | EncodedUpdate::TopKDelta { .. }
        | EncodedUpdate::TopKPacked { .. } => decode_update(base, enc),
        // Difference payloads dequantize, then add the base back.
        EncodedUpdate::QuantI8 { .. }
        | EncodedUpdate::QuantI8Group { .. }
        | EncodedUpdate::QuantI4Group { .. } => {
            let mut out = decode_update(base, enc)?;
            out.accumulate(base, 1.0)?;
            Ok(out)
        }
    }
}

/// Lossless sparse delta: every coordinate whose `f32` bits differ
/// between `base` and `target`, as packed replacement entries. Applying
/// it onto the same base ([`apply_delta`] / [`decode_update`])
/// reconstructs `target` bit for bit — the delta-checkpoint payload.
pub fn encode_changed(base: &ModelParams, target: &ModelParams) -> Result<EncodedUpdate> {
    check_delta_shapes(base, target)?;
    let bv = base.flat_values();
    let tv = target.flat_values();
    let entries: Vec<(u32, f32)> = tv
        .iter()
        .zip(bv.iter())
        .enumerate()
        .filter(|(_, (t, b))| t.to_bits() != b.to_bits())
        .map(|(i, (t, _))| (i as u32, *t))
        .collect();
    Ok(EncodedUpdate::TopKPacked { entries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_pair(seed: u64) -> (ModelParams, ModelParams) {
        let global = ModelParams::init(5, 4, 7, seed);
        let mut local = global.clone();
        let mut rng = Rng::new(seed ^ 0xabc);
        for t in local.tensors.iter_mut() {
            for v in t.data_mut() {
                *v += (rng.next_f32() - 0.5) * 0.2;
            }
        }
        (global, local)
    }

    #[test]
    fn parse_names() {
        assert_eq!(CodecSpec::parse("dense", 0.1).unwrap(), CodecSpec::Dense);
        assert_eq!(CodecSpec::parse("q8", 0.1).unwrap(), CodecSpec::QuantI8);
        assert_eq!(
            CodecSpec::parse("q8g", 0.1).unwrap(),
            CodecSpec::QuantI8Group { block: DEFAULT_Q8G_BLOCK }
        );
        assert_eq!(
            CodecSpec::parse("q8g:128", 0.1).unwrap(),
            CodecSpec::QuantI8Group { block: 128 }
        );
        assert_eq!(
            CodecSpec::parse("q4g", 0.1).unwrap(),
            CodecSpec::QuantI4Group { block: DEFAULT_Q4G_BLOCK }
        );
        assert_eq!(
            CodecSpec::parse("q4g:32", 0.1).unwrap(),
            CodecSpec::QuantI4Group { block: 32 }
        );
        assert_eq!(
            CodecSpec::parse("topk", 0.25).unwrap(),
            CodecSpec::TopK { frac: 0.25 }
        );
        assert_eq!(
            CodecSpec::parse("topkv", 0.25).unwrap(),
            CodecSpec::TopKPacked { frac: 0.25 }
        );
        assert!(CodecSpec::parse("topk", 0.0).is_err());
        assert!(CodecSpec::parse("topk", 1.5).is_err());
        assert!(CodecSpec::parse("topkv", 0.0).is_err());
        assert!(CodecSpec::parse("q8g:0", 0.1).is_err());
        assert!(CodecSpec::parse("q8g:half", 0.1).is_err());
        assert!(CodecSpec::parse("q4g:0", 0.1).is_err());
        assert!(CodecSpec::parse("q4g:half", 0.1).is_err());
        assert!(CodecSpec::parse("gzip", 0.1).is_err());
        // The unknown-codec error enumerates every family, q4g included.
        let err = CodecSpec::parse("gzip", 0.1).unwrap_err().to_string();
        for family in ["dense", "q8", "q8g", "q4g", "topk", "topkv"] {
            assert!(err.contains(family), "error must list {family}: {err}");
        }
    }

    #[test]
    fn spec_string_roundtrips_every_variant() {
        for spec in [
            CodecSpec::Dense,
            CodecSpec::QuantI8,
            CodecSpec::QuantI8Group { block: 64 },
            CodecSpec::QuantI8Group { block: 7 },
            CodecSpec::QuantI4Group { block: 64 },
            CodecSpec::QuantI4Group { block: 9 },
            CodecSpec::TopK { frac: 0.05 },
            CodecSpec::TopK { frac: 1.0 },
            CodecSpec::TopKPacked { frac: 0.37 },
        ] {
            // name() embeds everything the spec carries: re-parsing with
            // a *different* --topk-frac must reproduce it exactly.
            assert_eq!(
                CodecSpec::parse(&spec.name(), 0.99).unwrap(),
                spec,
                "{} must round-trip",
                spec.name()
            );
        }
        // An embedded fraction overrides the flag value…
        assert_eq!(
            CodecSpec::parse("topk:0.25", 0.9).unwrap(),
            CodecSpec::TopK { frac: 0.25 }
        );
        // …the historical 'quant' alias parses but normalizes to 'q8'…
        assert_eq!(CodecSpec::parse("quant", 0.1).unwrap().name(), "q8");
        // …and malformed spec strings are rejected, not ignored.
        assert!(CodecSpec::parse("dense:0.5", 0.1).is_err());
        assert!(CodecSpec::parse("q8:0.5", 0.1).is_err());
        assert!(CodecSpec::parse("topk:zero", 0.1).is_err());
        assert!(CodecSpec::parse("topk:0", 0.1).is_err());
        assert!(CodecSpec::parse("topk:nan", 0.1).is_err());
    }

    #[test]
    fn varint_roundtrip_and_lengths() {
        for v in [0u32, 1, 0x7f, 0x80, 0x3fff, 0x4000, 1 << 20, u32::MAX] {
            let mut buf = Vec::new();
            push_varint(&mut buf, v);
            assert_eq!(buf.len(), varint_len(v), "length of {v}");
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        // truncated stream fails
        let mut buf = Vec::new();
        push_varint(&mut buf, u32::MAX);
        let mut pos = 0;
        assert!(read_varint(&buf[..buf.len() - 1], &mut pos).is_err());
        // overlong / overflowing encodings are rejected
        let mut pos = 0;
        assert!(read_varint(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x01], &mut pos).is_err());
        let mut pos = 0;
        assert!(read_varint(&[0xff, 0xff, 0xff, 0xff, 0x7f], &mut pos).is_err());
    }

    #[test]
    fn packed_decodes_like_raw_topk() {
        let (global, local) = random_pair(6);
        for frac in [0.05f32, 0.3, 1.0] {
            let raw = encode_update(CodecSpec::TopK { frac }, &global, &local).unwrap();
            let packed =
                encode_update(CodecSpec::TopKPacked { frac }, &global, &local).unwrap();
            // identical selection...
            let (re, pe) = match (&raw, &packed) {
                (
                    EncodedUpdate::TopKDelta { entries: re },
                    EncodedUpdate::TopKPacked { entries: pe },
                ) => (re, pe),
                other => panic!("wrong variants {other:?}"),
            };
            assert_eq!(re, pe, "frac {frac}");
            // ...identical reconstruction...
            assert_eq!(
                decode_update(&global, &raw).unwrap(),
                decode_update(&global, &packed).unwrap()
            );
            // ...smaller wire payload (varint gaps beat raw u32 indices).
            assert!(
                packed.byte_len() < raw.byte_len(),
                "frac {frac}: packed {} >= raw {}",
                packed.byte_len(),
                raw.byte_len()
            );
        }
    }

    #[test]
    fn packed_bytes_roundtrip_and_validate() {
        let (global, local) = random_pair(7);
        let spec = CodecSpec::TopKPacked { frac: 0.25 };
        let enc = encode_update(spec, &global, &local).unwrap();
        let bytes = enc.to_bytes();
        assert_eq!(bytes.len(), enc.byte_len());
        let back =
            EncodedUpdate::from_bytes(spec, global.tensors.len(), global.num_params(), &bytes)
                .unwrap();
        assert_eq!(back, enc);
        // truncation is rejected
        assert!(
            EncodedUpdate::from_bytes(spec, 6, global.num_params(), &bytes[..bytes.len() - 1])
                .is_err()
        );
        // a zero gap after the first index (duplicate index) is rejected
        let mut bad = Vec::new();
        bad.extend_from_slice(&2u32.to_le_bytes());
        bad.push(3); // first index 3
        bad.push(0); // gap 0 → duplicate
        bad.extend_from_slice(&1.0f32.to_le_bytes());
        bad.extend_from_slice(&2.0f32.to_le_bytes());
        assert!(EncodedUpdate::from_bytes(spec, 6, 100, &bad).is_err());
    }

    #[test]
    fn dense_is_lossless_and_sized_like_the_model() {
        let (global, local) = random_pair(1);
        let enc = encode_update(CodecSpec::Dense, &global, &local).unwrap();
        assert_eq!(enc.byte_len(), local.byte_size());
        let back = decode_update(&global, &enc).unwrap();
        assert_eq!(back, local);
    }

    #[test]
    fn q8_error_is_scale_bounded() {
        let (global, local) = random_pair(2);
        let enc = encode_update(CodecSpec::QuantI8, &global, &local).unwrap();
        let back = decode_update(&global, &enc).unwrap();
        for (t_local, t_back) in local.tensors.iter().zip(back.tensors.iter()) {
            let max_abs = t_local.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = max_abs / 127.0;
            let err = t_local.max_abs_diff(t_back).unwrap();
            assert!(err <= scale * 0.5 + 1e-7, "err {err} vs scale {scale}");
        }
    }

    #[test]
    fn topk_full_fraction_reconstructs_exactly() {
        let (global, local) = random_pair(3);
        let enc = encode_update(CodecSpec::TopK { frac: 1.0 }, &global, &local).unwrap();
        let back = decode_update(&global, &enc).unwrap();
        assert_eq!(back, local);
    }

    #[test]
    fn topk_partial_touches_only_k_coordinates() {
        let (global, local) = random_pair(4);
        let n = global.num_params();
        let frac = 0.1f32;
        let enc = encode_update(CodecSpec::TopK { frac }, &global, &local).unwrap();
        let entries = match &enc {
            EncodedUpdate::TopKDelta { entries } => entries,
            other => panic!("wrong variant {other:?}"),
        };
        let k = ((n as f64 * frac as f64).ceil() as usize).clamp(1, n);
        assert_eq!(entries.len(), k);
        let back = decode_update(&global, &enc).unwrap();
        let (gf, lf, bf) = (global.flat_values(), local.flat_values(), back.flat_values());
        let mut kept = 0usize;
        for i in 0..n {
            if bf[i] == lf[i] && bf[i] != gf[i] {
                kept += 1;
            } else {
                assert_eq!(bf[i], gf[i], "coordinate {i} neither kept nor global");
            }
        }
        assert!(kept <= k);
    }

    #[test]
    fn bytes_roundtrip_every_codec() {
        let (global, local) = random_pair(5);
        let n_tensors = global.tensors.len();
        let n = global.num_params();
        for spec in [
            CodecSpec::Dense,
            CodecSpec::QuantI8,
            CodecSpec::QuantI8Group { block: 8 },
            CodecSpec::QuantI4Group { block: 8 },
            CodecSpec::QuantI4Group { block: 5 },
            CodecSpec::TopK { frac: 0.3 },
            CodecSpec::TopKPacked { frac: 0.3 },
        ] {
            let enc = encode_update(spec, &global, &local).unwrap();
            let bytes = enc.to_bytes();
            assert_eq!(bytes.len(), enc.byte_len(), "{}", enc.codec_name());
            let back = EncodedUpdate::from_bytes(spec, n_tensors, n, &bytes).unwrap();
            assert_eq!(back, enc);
        }
    }

    #[test]
    fn nibble_packing_roundtrips_even_and_odd_counts() {
        for count in [0usize, 1, 2, 3, 8, 9] {
            let values: Vec<i8> = (0..count).map(|i| ((i as i8) % 15) - 7).collect();
            let mut packed = Vec::new();
            pack_nibbles(&mut packed, &values);
            assert_eq!(packed.len(), count.div_ceil(2), "count {count}");
            let mut back = Vec::with_capacity(count);
            for (i, &b) in packed.iter().enumerate() {
                back.push(unpack_nibble(b));
                if 2 * i + 1 < count {
                    back.push(unpack_nibble(b >> 4));
                }
            }
            assert_eq!(back, values, "count {count}");
            // odd counts leave a zero padding nibble
            if count % 2 == 1 {
                assert_eq!(packed[count / 2] >> 4, 0, "count {count}");
            }
        }
    }

    #[test]
    fn q4g_error_is_block_scale_bounded() {
        let (global, local) = random_pair(31);
        let block = 8usize;
        let enc = encode_update(CodecSpec::QuantI4Group { block }, &global, &local).unwrap();
        let back = decode_update(&global, &enc).unwrap();
        for (t_local, t_back) in local.tensors.iter().zip(back.tensors.iter()) {
            let chunks = t_local.data().chunks(block).zip(t_back.data().chunks(block));
            for (chunk_l, chunk_b) in chunks {
                let max_abs = chunk_l.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = max_abs / 7.0;
                for (&a, &b) in chunk_l.iter().zip(chunk_b.iter()) {
                    let err = (a - b).abs();
                    assert!(err <= 0.5 * scale + 1e-7, "err {err} vs block scale {scale}");
                }
            }
        }
    }

    #[test]
    fn q4g_bytes_are_at_most_055_of_q8g_at_the_same_block() {
        // The headline ratio the benches pin in CI: at block 64 the
        // value stream halves and the shared scale overhead keeps the
        // total at ≈0.53× — comfortably under the 0.55 budget.
        let global = ModelParams::init(64, 32, 128, 41);
        let local = global.clone();
        let block = 64usize;
        let q8g = encode_update(CodecSpec::QuantI8Group { block }, &global, &local).unwrap();
        let q4g = encode_update(CodecSpec::QuantI4Group { block }, &global, &local).unwrap();
        let ratio = q4g.byte_len() as f64 / q8g.byte_len() as f64;
        assert!(ratio <= 0.55, "q4g/q8g byte ratio {ratio} > 0.55");
    }

    #[test]
    fn q4g_rejects_corrupt_payloads() {
        let (global, local) = random_pair(33);
        let spec = CodecSpec::QuantI4Group { block: 4 };
        let enc = encode_update(spec, &global, &local).unwrap();
        let bytes = enc.to_bytes();
        let n = global.num_params();
        assert_eq!(n % 2, 1, "test model should exercise the padding nibble");
        // truncation is rejected (mid-values, mid-scales, mid-header)
        assert!(EncodedUpdate::from_bytes(spec, 6, n, &bytes[..bytes.len() - 1]).is_err());
        assert!(EncodedUpdate::from_bytes(spec, 6, n, &bytes[..5]).is_err());
        assert!(EncodedUpdate::from_bytes(spec, 6, n, &bytes[..3]).is_err());
        // a forged scale-count header breaks the exact-length equation
        let mut forged = bytes.clone();
        forged[0..4].copy_from_slice(&((n as u32) + 1).to_le_bytes());
        assert!(EncodedUpdate::from_bytes(spec, 6, n, &forged).is_err());
        // nonzero padding in the final high nibble is rejected
        let mut padded = bytes.clone();
        let last = padded.len() - 1;
        padded[last] |= 0xf0;
        assert!(EncodedUpdate::from_bytes(spec, 6, n, &padded).is_err());
        // a scale count that disagrees with the model shape is rejected
        // at decode time even when the payload length is self-consistent
        let bad = EncodedUpdate::QuantI4Group {
            block: 4,
            scales: vec![0.1f32; 3],
            values: vec![0i8; n],
        };
        assert!(decode_update(&global, &bad).is_err());
        // a wrong value count is rejected
        let bad = EncodedUpdate::QuantI4Group {
            block: 4,
            scales: vec![0.1f32; 2],
            values: vec![0i8; 7],
        };
        assert!(decode_update(&global, &bad).is_err());
    }

    #[test]
    fn q4g_rejects_non_finite_updates() {
        let global = ModelParams::zeros(2, 2, 2);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut local = global.clone();
            local.tensors[0].data_mut()[1] = bad;
            assert!(
                encode_update(CodecSpec::QuantI4Group { block: 4 }, &global, &local).is_err(),
                "q4g must reject {bad}"
            );
        }
    }

    #[test]
    fn delta_q4_quantizes_the_difference() {
        let (base, target) = random_pair(34);
        let enc = encode_delta(CodecSpec::QuantI4Group { block: 8 }, &base, &target).unwrap();
        let back = apply_delta(&base, &enc).unwrap();
        let (bv, tv, rv) = (base.flat_values(), target.flat_values(), back.flat_values());
        let max_diff = bv
            .iter()
            .zip(tv.iter())
            .fold(0.0f32, |m, (b, t)| m.max((t - b).abs()));
        let bound = max_diff / 7.0 * 0.5 + 1e-6;
        for (t, r) in tv.iter().zip(rv.iter()) {
            assert!((t - r).abs() <= bound + 1e-6, "err {} vs {bound}", (t - r).abs());
        }
    }

    #[test]
    fn q8g_error_is_block_scale_bounded() {
        let (global, local) = random_pair(12);
        let block = 8usize;
        let enc = encode_update(CodecSpec::QuantI8Group { block }, &global, &local).unwrap();
        let back = decode_update(&global, &enc).unwrap();
        for (t_local, t_back) in local.tensors.iter().zip(back.tensors.iter()) {
            let chunks = t_local.data().chunks(block).zip(t_back.data().chunks(block));
            for (chunk_l, chunk_b) in chunks {
                let max_abs = chunk_l.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = max_abs / 127.0;
                for (&a, &b) in chunk_l.iter().zip(chunk_b.iter()) {
                    let err = (a - b).abs();
                    assert!(err <= 0.5 * scale + 1e-7, "err {err} vs block scale {scale}");
                }
            }
        }
    }

    #[test]
    fn q8g_beats_q8_under_an_outlier() {
        // One huge coordinate inflates the per-tensor q8 scale for the
        // whole tensor; group-wise scales quarantine it to one block.
        let global = ModelParams::zeros(8, 4, 4);
        let mut local = global.clone();
        let mut rng = Rng::new(77);
        for v in local.tensors[0].data_mut() {
            *v = (rng.next_f32() - 0.5) * 0.02;
        }
        local.tensors[0].data_mut()[0] = 10.0;
        let q8 = decode_update(
            &global,
            &encode_update(CodecSpec::QuantI8, &global, &local).unwrap(),
        )
        .unwrap();
        let q8g = decode_update(
            &global,
            &encode_update(CodecSpec::QuantI8Group { block: 8 }, &global, &local).unwrap(),
        )
        .unwrap();
        // Error on the non-outlier tail (everything past the first block).
        let tail_err = |m: &ModelParams| -> f32 {
            m.tensors[0].data()[8..]
                .iter()
                .zip(local.tensors[0].data()[8..].iter())
                .fold(0.0f32, |acc, (a, b)| acc.max((a - b).abs()))
        };
        assert!(
            tail_err(&q8g) < tail_err(&q8),
            "q8g tail error {} must beat q8 {}",
            tail_err(&q8g),
            tail_err(&q8)
        );
    }

    #[test]
    fn q8g_rejects_corrupt_payloads() {
        let (global, local) = random_pair(13);
        let spec = CodecSpec::QuantI8Group { block: 4 };
        let enc = encode_update(spec, &global, &local).unwrap();
        let bytes = enc.to_bytes();
        let n = global.num_params();
        // truncation is rejected
        assert!(EncodedUpdate::from_bytes(spec, 6, n, &bytes[..bytes.len() - 1]).is_err());
        assert!(EncodedUpdate::from_bytes(spec, 6, n, &bytes[..3]).is_err());
        // a scale count that disagrees with the model shape is rejected
        // at decode time even when the payload length is self-consistent
        let bad = EncodedUpdate::QuantI8Group {
            block: 4,
            scales: vec![0.1f32; 3],
            values: vec![0i8; n],
        };
        assert!(decode_update(&global, &bad).is_err());
        // a wrong value count is rejected
        let bad = EncodedUpdate::QuantI8Group {
            block: 4,
            scales: vec![0.1f32; 2],
            values: vec![0i8; 7],
        };
        assert!(decode_update(&global, &bad).is_err());
    }

    #[test]
    fn delta_sparse_is_replacement_semantics() {
        let (base, target) = random_pair(14);
        for spec in [CodecSpec::TopK { frac: 0.2 }, CodecSpec::TopKPacked { frac: 0.2 }] {
            let enc = encode_delta(spec, &base, &target).unwrap();
            assert_eq!(enc, encode_update(spec, &base, &target).unwrap());
            assert_eq!(
                apply_delta(&base, &enc).unwrap(),
                decode_update(&base, &enc).unwrap()
            );
        }
        // Dense "delta" ships the full target, losslessly.
        let enc = encode_delta(CodecSpec::Dense, &base, &target).unwrap();
        assert_eq!(apply_delta(&base, &enc).unwrap(), target);
    }

    #[test]
    fn delta_q8_quantizes_the_difference() {
        let (base, target) = random_pair(15);
        for spec in [CodecSpec::QuantI8, CodecSpec::QuantI8Group { block: 8 }] {
            let enc = encode_delta(spec, &base, &target).unwrap();
            let back = apply_delta(&base, &enc).unwrap();
            // The diff here is bounded by ±0.1 (random_pair), so every
            // reconstructed coordinate is within the diff's scale bound —
            // far tighter than quantizing the absolute values.
            let (bv, tv, rv) = (base.flat_values(), target.flat_values(), back.flat_values());
            let max_diff = bv
                .iter()
                .zip(tv.iter())
                .fold(0.0f32, |m, (b, t)| m.max((t - b).abs()));
            let bound = max_diff / 127.0 * 0.5 + 1e-6;
            for (t, r) in tv.iter().zip(rv.iter()) {
                assert!((t - r).abs() <= bound + 1e-6, "err {} vs {bound}", (t - r).abs());
            }
        }
    }

    #[test]
    fn changed_entries_are_exact_and_minimal() {
        let (base, _) = random_pair(16);
        let mut target = base.clone();
        // flip three coordinates, one to NaN-free extreme values
        target.tensors[0].data_mut()[1] = 5.0;
        target.tensors[2].data_mut()[0] = -3.5;
        target.tensors[5].data_mut()[2] = 0.25;
        let enc = encode_changed(&base, &target).unwrap();
        let entries = match &enc {
            EncodedUpdate::TopKPacked { entries } => entries,
            other => panic!("wrong variant {other:?}"),
        };
        assert_eq!(entries.len(), 3, "exactly the changed coordinates ship");
        assert_eq!(apply_delta(&base, &enc).unwrap(), target, "bitwise reconstruction");
        // identical models produce an empty (4-byte) delta
        let empty = encode_changed(&base, &base).unwrap();
        assert_eq!(empty.byte_len(), 4);
        assert_eq!(apply_delta(&base, &empty).unwrap(), base);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let a = ModelParams::zeros(2, 2, 2);
        let b = ModelParams::zeros(3, 2, 2);
        assert!(encode_update(CodecSpec::Dense, &a, &b).is_err());
    }

    #[test]
    fn q8_rejects_non_finite_updates() {
        let global = ModelParams::zeros(2, 2, 2);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut local = global.clone();
            local.tensors[0].data_mut()[1] = bad;
            let err = encode_update(CodecSpec::QuantI8, &global, &local);
            assert!(err.is_err(), "q8 must reject {bad}");
        }
        // dense still round-trips non-finite values (visibly, not silently)
        let mut local = global.clone();
        local.tensors[0].data_mut()[0] = f32::INFINITY;
        let enc = encode_update(CodecSpec::Dense, &global, &local).unwrap();
        let back = decode_update(&global, &enc).unwrap();
        assert!(back.tensors[0].data()[0].is_infinite());
    }

    #[test]
    fn all_zero_model_quantizes_to_zero_scales() {
        let z = ModelParams::zeros(3, 2, 4);
        let enc = encode_update(CodecSpec::QuantI8, &z, &z).unwrap();
        let back = decode_update(&z, &enc).unwrap();
        assert_eq!(back, z);
    }

    #[test]
    fn framed_roundtrip_every_codec() {
        let (global, local) = random_pair(21);
        let (nt, n) = (global.tensors.len(), global.num_params());
        for spec in [
            CodecSpec::Dense,
            CodecSpec::QuantI8,
            CodecSpec::QuantI8Group { block: 8 },
            CodecSpec::QuantI4Group { block: 8 },
            CodecSpec::TopK { frac: 0.3 },
            CodecSpec::TopKPacked { frac: 0.3 },
        ] {
            let enc = encode_update(spec, &global, &local).unwrap();
            let framed = enc.to_framed_bytes();
            assert_eq!(framed.len(), enc.framed_len(), "{}", enc.codec_name());
            assert_eq!(framed.len(), enc.byte_len() + FRAME_OVERHEAD);
            let back = EncodedUpdate::from_framed_bytes(spec, nt, n, &framed).unwrap();
            assert_eq!(back, enc, "{}", enc.codec_name());
        }
    }

    #[test]
    fn framed_decode_rejects_every_single_byte_flip() {
        // FNV-1a's per-byte step is bijective, so any one-byte change —
        // header, payload, or the checksum itself — must fail decode.
        let (global, local) = random_pair(22);
        let (nt, n) = (global.tensors.len(), global.num_params());
        let spec = CodecSpec::QuantI8;
        let framed = encode_update(spec, &global, &local)
            .unwrap()
            .to_framed_bytes();
        for i in 0..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0x01;
            assert!(
                EncodedUpdate::from_framed_bytes(spec, nt, n, &bad).is_err(),
                "flip at byte {i} must be rejected"
            );
        }
    }

    #[test]
    fn framed_decode_rejects_truncation_and_wrong_codec() {
        let (global, local) = random_pair(23);
        let (nt, n) = (global.tensors.len(), global.num_params());
        let spec = CodecSpec::TopKPacked { frac: 0.5 };
        let framed = encode_update(spec, &global, &local)
            .unwrap()
            .to_framed_bytes();
        for cut in [0, 1, FRAME_OVERHEAD - 1, framed.len() / 2, framed.len() - 1] {
            assert!(
                EncodedUpdate::from_framed_bytes(spec, nt, n, &framed[..cut]).is_err(),
                "truncation to {cut} bytes must be rejected"
            );
        }
        // The frame names its codec; decoding as another family fails
        // before the payload parser ever runs.
        let err =
            EncodedUpdate::from_framed_bytes(CodecSpec::Dense, nt, n, &framed).unwrap_err();
        assert!(err.to_string().contains("codec tag"), "{err}");
        // An oversized declared length is rejected up front.
        let mut oversized = framed.clone();
        oversized[3..7].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = EncodedUpdate::from_framed_bytes(spec, nt, n, &oversized).unwrap_err();
        assert!(err.to_string().contains("declares"), "{err}");
    }
}
