//! Observability: metrics, span tracing, and leveled logging.
//!
//! Zero-dependency telemetry shared by the round engine, the async
//! simulator, the transport layer, and the serve path:
//!
//! * [`metrics`] — a thread-safe registry of counters, gauges, and
//!   fixed-bucket histograms with Prometheus text exposition. The
//!   process-global registry ([`metrics::global()`]) is scraped by
//!   `GET /metrics?format=prometheus` alongside the serve-local window
//!   metrics. The serving control plane publishes its lifecycle here:
//!   `fedmlh_serve_reloads_total{result}`,
//!   `fedmlh_serve_rollout_transitions_total{to}`, the
//!   `fedmlh_serve_generation` gauge, and per-version / per-replica
//!   request and error series labeled by `generation` (and `replica`).
//! * [`trace`] — a span tracer exporting Chrome-trace-event JSON
//!   (open in Perfetto or `chrome://tracing`). Sync rounds and kernel
//!   sections record wall-clock spans; async simulation records spans on
//!   the *simulated* clock, so stragglers / buffer flushes / dropout are
//!   visible at million-client scale. Enabled by `--trace-out <path>`.
//! * [`log`] — `log_error!` / `log_warn!` / `log_info!` / `log_debug!`
//!   macros behind a global threshold set by `--log-level` (and lowered
//!   to `error` by `--quiet`).
//!
//! All three are near-zero-cost when disabled (one relaxed atomic load)
//! and strictly observational: instrumentation never feeds back into RNG
//! draws, event ordering, or model arithmetic, so bitwise determinism is
//! preserved with tracing on.

pub mod log;
pub mod metrics;
pub mod trace;
