//! Leveled logging with a process-global threshold.
//!
//! The crate historically wrote progress chatter straight to stderr via
//! `eprintln!`. Those call sites now route through the [`log_error!`],
//! [`log_warn!`], [`log_info!`] and [`log_debug!`] macros, which check a
//! single atomic level before formatting anything. `--log-level error`
//! therefore silences progress output in scripted runs without touching
//! result printing on stdout.
//!
//! The fast path is one relaxed atomic load; a disabled level never
//! evaluates its format arguments.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or strongly unexpected conditions.
    Error = 0,
    /// Degraded behavior the run can continue through.
    Warn = 1,
    /// Progress chatter (default).
    Info = 2,
    /// High-volume diagnostics.
    Debug = 3,
}

impl Level {
    /// Parse a CLI level name. Accepts `error|warn|info|debug`.
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    /// Lowercase name, matching what [`Level::parse`] accepts.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Current threshold; messages with `level as u8 <= LEVEL` are emitted.
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log threshold.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log threshold.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Whether a message at `level` would be emitted right now.
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a pre-checked message. Called by the logging macros; the level
/// check happens again here so direct callers stay correct.
pub fn emit(level: Level, args: std::fmt::Arguments) {
    if enabled(level) {
        eprintln!("[{}] {args}", level.name());
    }
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Error, format_args!($($arg)*))
    };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_names() {
        for lvl in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(lvl.name()), Some(lvl));
        }
        assert_eq!(Level::parse("verbose"), None);
    }

    #[test]
    fn threshold_orders_levels() {
        // Error is always enabled regardless of threshold; Debug only at Debug.
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
