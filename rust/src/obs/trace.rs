//! Span tracer with Chrome-trace-event JSON export (Perfetto-loadable).
//!
//! Spans are recorded into an in-memory buffer and written out once at the
//! end of a run (`--trace-out <path>`). Two tracks exist:
//!
//! * **pid [`SIM_PID`] "simulated"** — spans stamped with the async
//!   simulator's *virtual* clock ([`sim_span`] / [`sim_instant`]). A
//!   million-client trace shows stragglers, buffer flushes, and dropout
//!   on the timeline the algorithm actually experienced.
//! * **pid [`WALL_PID`] "wall-clock"** — real elapsed time measured from
//!   the tracer's install instant ([`wall_span`]), used by the sync round
//!   loop, the engine workers, and `util/timer.rs` kernel sections.
//!
//! Tracing is off unless [`install`] is called; every helper first checks
//! one relaxed [`AtomicBool`], so the disabled cost is a single load.
//! Recording never feeds back into RNG draws, event ordering, or float
//! arithmetic, so enabling it cannot perturb bitwise determinism.
//!
//! Open an exported file at <https://ui.perfetto.dev> (drag and drop) or
//! `chrome://tracing`.

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Track id for simulated-clock events.
pub const SIM_PID: u64 = 0;
/// Track id for wall-clock events.
pub const WALL_PID: u64 = 1;

/// One Chrome trace event (a subset of the format: complete spans `X`,
/// instants `i`, metadata `M`).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Event name shown on the timeline.
    pub name: String,
    /// Process track (see [`SIM_PID`] / [`WALL_PID`]).
    pub pid: u64,
    /// Thread lane within the track.
    pub tid: u64,
    /// Start timestamp in microseconds.
    pub ts_us: f64,
    /// Duration in microseconds (complete spans only).
    pub dur_us: f64,
    /// Phase: `X` complete span, `i` instant, `M` metadata.
    pub ph: char,
    /// Extra key/value payload rendered under `args`.
    pub args: Vec<(String, Json)>,
}

/// In-memory trace recorder.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    events: Mutex<Vec<TraceEvent>>,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

impl Tracer {
    /// Fresh tracer; wall-clock timestamps are relative to this call.
    pub fn new() -> Tracer {
        Tracer {
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
        }
    }

    /// Microseconds of wall time since the tracer was created.
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// Append an event.
    pub fn record(&self, ev: TraceEvent) {
        self.events.lock().unwrap().push(ev);
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// Whether nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render the Chrome trace JSON (`{"traceEvents":[...]}`). Events are
    /// sorted by timestamp so each track is monotone; track-name metadata
    /// events lead the array.
    pub fn to_chrome_json(&self) -> Json {
        let mut events = self.events.lock().unwrap().clone();
        events.sort_by(|a, b| {
            a.ts_us
                .total_cmp(&b.ts_us)
                .then_with(|| a.pid.cmp(&b.pid))
                .then_with(|| a.tid.cmp(&b.tid))
        });
        let mut arr: Vec<Json> = Vec::with_capacity(events.len() + 2);
        for (pid, label) in [(SIM_PID, "simulated"), (WALL_PID, "wall-clock")] {
            arr.push(Json::obj(vec![
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(0.0)),
                ("args", Json::obj(vec![("name", Json::str(label))])),
            ]));
        }
        for ev in &events {
            let mut fields: Vec<(&str, Json)> = vec![
                ("name", Json::str(&ev.name)),
                ("ph", Json::str(&ev.ph.to_string())),
                ("ts", Json::num(ev.ts_us)),
                ("pid", Json::num(ev.pid as f64)),
                ("tid", Json::num(ev.tid as f64)),
            ];
            if ev.ph == 'X' {
                fields.push(("dur", Json::num(ev.dur_us)));
            }
            if ev.ph == 'i' {
                // Instant scope: thread.
                fields.push(("s", Json::str("t")));
            }
            if !ev.args.is_empty() {
                let args: Vec<(&str, Json)> = ev
                    .args
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect();
                fields.push(("args", Json::obj(args)));
            }
            arr.push(Json::obj(fields));
        }
        Json::obj(vec![("traceEvents", Json::Arr(arr))])
    }

    /// Write the trace to `path` as Chrome trace JSON.
    pub fn write_chrome_trace(&self, path: &std::path::Path) -> std::io::Result<()> {
        let json = self.to_chrome_json().to_string_pretty(2);
        let mut f = std::fs::File::create(path)?;
        f.write_all(json.as_bytes())?;
        f.write_all(b"\n")
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static TRACER: OnceLock<Tracer> = OnceLock::new();

/// Install the process-global tracer and enable recording. Idempotent;
/// returns the tracer.
pub fn install() -> &'static Tracer {
    let t = TRACER.get_or_init(Tracer::new);
    ENABLED.store(true, Ordering::Relaxed);
    t
}

/// Whether tracing is currently enabled (one relaxed load).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The installed tracer, if tracing is enabled.
pub fn tracer() -> Option<&'static Tracer> {
    if enabled() {
        TRACER.get()
    } else {
        None
    }
}

/// RAII guard recording a wall-clock complete span on drop.
pub struct SpanGuard {
    tracer: &'static Tracer,
    name: String,
    tid: u64,
    start_us: f64,
    args: Vec<(String, Json)>,
}

impl SpanGuard {
    /// Attach an extra `args` entry to the span.
    pub fn arg(mut self, key: &str, value: Json) -> SpanGuard {
        self.args.push((key.to_string(), value));
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end_us = self.tracer.now_us();
        self.tracer.record(TraceEvent {
            name: std::mem::take(&mut self.name),
            pid: WALL_PID,
            tid: self.tid,
            ts_us: self.start_us,
            dur_us: (end_us - self.start_us).max(0.0),
            ph: 'X',
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Start a wall-clock span on lane `tid`; the span ends when the returned
/// guard drops. Returns `None` (and costs one atomic load) when tracing
/// is disabled.
pub fn wall_span(name: &str, tid: u64) -> Option<SpanGuard> {
    let t = tracer()?;
    Some(SpanGuard {
        tracer: t,
        name: name.to_string(),
        tid,
        start_us: t.now_us(),
        args: Vec::new(),
    })
}

/// Record a wall-clock instant event (ph `i`) on lane `tid` at "now".
/// Used for point-in-time state transitions (e.g. the serve control
/// plane's canary promoted/rolled-back markers).
pub fn wall_instant(name: &str, tid: u64, args: Vec<(String, Json)>) {
    if let Some(t) = tracer() {
        t.record(TraceEvent {
            name: name.to_string(),
            pid: WALL_PID,
            tid,
            ts_us: t.now_us(),
            dur_us: 0.0,
            ph: 'i',
            args,
        });
    }
}

/// Record a simulated-clock complete span from `start_s` to `end_s`
/// (seconds of virtual time) on lane `tid`.
pub fn sim_span(name: &str, tid: u64, start_s: f64, end_s: f64, args: Vec<(String, Json)>) {
    if let Some(t) = tracer() {
        t.record(TraceEvent {
            name: name.to_string(),
            pid: SIM_PID,
            tid,
            ts_us: start_s * 1e6,
            dur_us: (end_s - start_s).max(0.0) * 1e6,
            ph: 'X',
            args,
        });
    }
}

/// Record a simulated-clock instant event at `t_s` seconds on lane `tid`.
pub fn sim_instant(name: &str, tid: u64, t_s: f64, args: Vec<(String, Json)>) {
    if let Some(t) = tracer() {
        t.record(TraceEvent {
            name: name.to_string(),
            pid: SIM_PID,
            tid,
            ts_us: t_s * 1e6,
            dur_us: 0.0,
            ph: 'i',
            args,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_json_is_well_formed_and_sorted() {
        let t = Tracer::new();
        t.record(TraceEvent {
            name: "late".into(),
            pid: SIM_PID,
            tid: 1,
            ts_us: 2_000_000.0,
            dur_us: 500_000.0,
            ph: 'X',
            args: vec![("client".into(), Json::num(7.0))],
        });
        t.record(TraceEvent {
            name: "early".into(),
            pid: SIM_PID,
            tid: 0,
            ts_us: 1_000_000.0,
            dur_us: 0.0,
            ph: 'i',
            args: vec![],
        });
        let json = t.to_chrome_json();
        let rendered = json.to_string_pretty(2);
        let parsed = Json::parse(&rendered).expect("trace JSON parses");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 recorded.
        assert_eq!(events.len(), 4);
        // Recorded events are sorted by ts.
        let data: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() != "M")
            .collect();
        assert_eq!(data[0].get("name").unwrap().as_str().unwrap(), "early");
        assert_eq!(data[1].get("name").unwrap().as_str().unwrap(), "late");
        // Instant events carry the scope field; spans carry dur.
        assert_eq!(data[0].get("s").unwrap().as_str().unwrap(), "t");
        assert_eq!(data[1].get("dur").unwrap().as_f64().unwrap(), 500_000.0);
        let client = data[1].get("args").unwrap().get("client").unwrap();
        assert_eq!(client.as_f64().unwrap(), 7.0);
    }

    #[test]
    fn helpers_are_noops_when_disabled() {
        // The global tracer may have been installed by another test in this
        // process; only assert the local-tracer behavior here.
        let t = Tracer::new();
        assert!(t.is_empty());
        sim_span("x", 0, 0.0, 1.0, vec![]); // must not panic either way
    }

    #[test]
    fn span_guard_records_on_drop() {
        let t: &'static Tracer = Box::leak(Box::new(Tracer::new()));
        {
            let g = SpanGuard {
                tracer: t,
                name: "scoped".into(),
                tid: 3,
                start_us: 0.0,
                args: vec![],
            }
            .arg("k", Json::num(1.0));
            drop(g);
        }
        assert_eq!(t.len(), 1);
        let json = t.to_chrome_json().to_string_pretty(2);
        let parsed = Json::parse(&json).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let span = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "scoped")
            .unwrap();
        assert_eq!(span.get("tid").unwrap().as_f64().unwrap(), 3.0);
        assert!(span.get("dur").unwrap().as_f64().unwrap() >= 0.0);
    }
}
