//! Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`s over
//! atomics — updating one is lock-free and safe from any thread, including
//! the engine's worker pool. The [`MetricsRegistry`] owns the name →
//! series map (a lock is taken only at registration and render time) and
//! renders the whole collection in the Prometheus text exposition format.
//!
//! A process-global registry ([`global()`]) backs the train/sim/transport
//! instrumentation; the serve path additionally keeps its windowed
//! [`crate::serve::ServeMetrics`] and renders both on
//! `GET /metrics?format=prometheus`.
//!
//! Naming convention: everything registered here is `fedmlh_*`, counters
//! end in `_total`, and serve-local metrics use the disjoint
//! `fedmlh_serve_*` prefix so the two renders concatenate without
//! collisions.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonically increasing integer metric.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous float metric (stored as f64 bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    /// Set the gauge to `v`.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram. Bucket `i` counts observations `v <= uppers[i]`
/// (non-cumulative internally); one extra overflow slot catches the rest.
#[derive(Debug)]
pub struct Histogram {
    uppers: Vec<f64>,
    counts: Vec<AtomicU64>, // len = uppers.len() + 1 (overflow / +Inf)
    sum_bits: AtomicU64,
    total: AtomicU64,
}

impl Histogram {
    fn new(uppers: &[f64]) -> Histogram {
        debug_assert!(
            uppers.windows(2).all(|w| w[0] < w[1]),
            "histogram bucket bounds must be strictly increasing"
        );
        Histogram {
            uppers: uppers.to_vec(),
            counts: (0..uppers.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            total: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        let idx = self
            .uppers
            .iter()
            .position(|&u| v <= u)
            .unwrap_or(self.uppers.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        // CAS loop: atomics have no f64 fetch_add.
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Cumulative `(upper_bound, count<=bound)` pairs; the last entry is
    /// `(f64::INFINITY, count())` as Prometheus requires.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.counts.len());
        let mut running = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            running += c.load(Ordering::Relaxed);
            let upper = self.uppers.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((upper, running));
        }
        out
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    fn prom_type(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

#[derive(Debug, Clone)]
enum Series {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

#[derive(Debug)]
struct Family {
    help: String,
    kind: MetricKind,
    // Keyed by the rendered label set (`{k="v",...}` or "") so
    // re-registration returns the existing handle.
    series: BTreeMap<String, Series>,
}

/// Thread-safe collection of named metric families.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    families: Mutex<BTreeMap<String, Family>>,
}

fn label_key(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        // Escape per the Prometheus text format.
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"");
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(&escaped);
        out.push('"');
    }
    out.push('}');
    out
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn register(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Series,
        kind: MetricKind,
    ) -> Series {
        let key = label_key(labels);
        let mut fams = self.families.lock().unwrap();
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            series: BTreeMap::new(),
        });
        assert!(
            fam.kind == kind,
            "metric '{name}' re-registered as a different kind"
        );
        fam.series.entry(key).or_insert_with(make).clone()
    }

    /// Register (or look up) an unlabeled counter.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, help, &[])
    }

    /// Register (or look up) a labeled counter.
    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.register(
            name,
            help,
            labels,
            || Series::Counter(Arc::new(Counter::default())),
            MetricKind::Counter,
        ) {
            Series::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    /// Register (or look up) an unlabeled gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.gauge_with(name, help, &[])
    }

    /// Register (or look up) a labeled gauge.
    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.register(
            name,
            help,
            labels,
            || Series::Gauge(Arc::new(Gauge::default())),
            MetricKind::Gauge,
        ) {
            Series::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    /// Register (or look up) an unlabeled histogram with the given
    /// strictly increasing bucket upper bounds (`+Inf` is implicit).
    pub fn histogram(&self, name: &str, help: &str, uppers: &[f64]) -> Arc<Histogram> {
        self.histogram_with(name, help, uppers, &[])
    }

    /// Register (or look up) a labeled histogram.
    pub fn histogram_with(
        &self,
        name: &str,
        help: &str,
        uppers: &[f64],
        labels: &[(&str, &str)],
    ) -> Arc<Histogram> {
        match self.register(
            name,
            help,
            labels,
            || Series::Histogram(Arc::new(Histogram::new(uppers))),
            MetricKind::Histogram,
        ) {
            Series::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    /// Render every family in the Prometheus text exposition format.
    pub fn render_prometheus(&self) -> String {
        let fams = self.families.lock().unwrap();
        let mut out = String::new();
        for (name, fam) in fams.iter() {
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind.prom_type()));
            for (labels, series) in fam.series.iter() {
                match series {
                    Series::Counter(c) => {
                        out.push_str(&format!("{name}{labels} {}\n", c.get()));
                    }
                    Series::Gauge(g) => {
                        out.push_str(&format!("{name}{labels} {}\n", fmt_f64(g.get())));
                    }
                    Series::Histogram(h) => {
                        // One bucket snapshot feeds both `_bucket` and
                        // `_count`: `+Inf` must equal `_count` even if
                        // another thread is observing mid-render.
                        let buckets = h.buckets();
                        let total = buckets.last().map_or(0, |&(_, c)| c);
                        for (upper, count) in buckets {
                            let le = if upper.is_infinite() {
                                "+Inf".to_string()
                            } else {
                                fmt_f64(upper)
                            };
                            let merged = merge_le(labels, &le);
                            out.push_str(&format!("{name}_bucket{merged} {count}\n"));
                        }
                        out.push_str(&format!("{name}_sum{labels} {}\n", fmt_f64(h.sum())));
                        out.push_str(&format!("{name}_count{labels} {total}\n"));
                    }
                }
            }
        }
        out
    }
}

/// Merge an `le` label into an existing rendered label set.
fn merge_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        // labels is "{k=\"v\",...}" — splice before the closing brace.
        let inner = &labels[..labels.len() - 1];
        format!("{inner},le=\"{le}\"}}")
    }
}

/// Render an f64 the way Prometheus expects (integers without a trailing
/// `.0`, everything else via the default float formatter).
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-global registry used by train/sim/transport instrumentation.
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("fedmlh_test_total", "test counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Re-registration returns the same underlying series.
        let c2 = reg.counter("fedmlh_test_total", "test counter");
        c2.inc();
        assert_eq!(c.get(), 6);

        let g = reg.gauge("fedmlh_test_gauge", "test gauge");
        g.set(0.25);
        assert_eq!(g.get(), 0.25);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("fedmlh_test_hist", "test hist", &[1.0, 2.0, 4.0]);
        // Exactly-on-boundary lands in that bucket (le semantics).
        h.observe(1.0);
        h.observe(1.5);
        h.observe(4.0);
        h.observe(100.0); // overflow
        let b = h.buckets();
        assert_eq!(b.len(), 4);
        assert_eq!(b[0], (1.0, 1)); // v=1.0
        assert_eq!(b[1], (2.0, 2)); // + v=1.5
        assert_eq!(b[2], (4.0, 3)); // + v=4.0
        assert!(b[3].0.is_infinite());
        assert_eq!(b[3].1, 4); // + v=100
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 106.5).abs() < 1e-9);
    }

    #[test]
    fn prometheus_render_shape() {
        let reg = MetricsRegistry::new();
        reg.counter("fedmlh_rounds_total", "rounds run").add(3);
        reg.gauge("fedmlh_accuracy", "top-1").set(0.5);
        let h = reg.histogram("fedmlh_lat_seconds", "latency", &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(5.0);
        let text = reg.render_prometheus();
        assert!(text.contains("# HELP fedmlh_rounds_total rounds run\n"));
        assert!(text.contains("# TYPE fedmlh_rounds_total counter\n"));
        assert!(text.contains("fedmlh_rounds_total 3\n"));
        assert!(text.contains("fedmlh_accuracy 0.5\n"));
        assert!(text.contains("fedmlh_lat_seconds_bucket{le=\"0.1\"} 1\n"));
        assert!(text.contains("fedmlh_lat_seconds_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("fedmlh_lat_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("fedmlh_lat_seconds_sum 5.05\n"));
        assert!(text.contains("fedmlh_lat_seconds_count 2\n"));
    }

    #[test]
    fn labeled_series_render_sorted() {
        let reg = MetricsRegistry::new();
        reg.counter_with("fedmlh_bytes_total", "bytes", &[("dir", "up")])
            .add(10);
        reg.counter_with("fedmlh_bytes_total", "bytes", &[("dir", "down")])
            .add(20);
        let text = reg.render_prometheus();
        let down = text.find("fedmlh_bytes_total{dir=\"down\"} 20").unwrap();
        let up = text.find("fedmlh_bytes_total{dir=\"up\"} 10").unwrap();
        assert!(down < up, "series render in sorted label order");
        // HELP/TYPE appear exactly once for the family.
        assert_eq!(text.matches("# TYPE fedmlh_bytes_total").count(), 1);
    }
}
