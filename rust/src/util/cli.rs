//! Declarative command-line flag parsing (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with generated `--help` text. Used by the
//! `fedmlh` binary and the examples.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// One declared flag.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative arg parser: declare flags, then [`Args::parse`].
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    flags: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare a value flag with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required value flag.
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Declare a boolean switch (false unless present).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.program, self.about);
        for f in &self.flags {
            let d = match (&f.default, f.is_bool) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        s
    }

    /// Parse a token list (no program name).
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed> {
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| anyhow!("unknown flag --{name}\n\n{}", self.usage()))?
                    .clone();
                let value = if spec.is_bool {
                    match inline {
                        Some(v) => v,
                        None => "true".to_string(),
                    }
                } else {
                    match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow!("flag --{name} needs a value"))?
                                .clone()
                        }
                    }
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(tok.clone());
            }
            i += 1;
        }
        // defaults + required check
        for f in &self.flags {
            if !self.values.contains_key(&f.name) {
                match &f.default {
                    Some(d) => {
                        self.values.insert(f.name.clone(), d.clone());
                    }
                    None => bail!("missing required flag --{}\n\n{}", f.name, self.usage()),
                }
            }
        }
        Ok(Parsed {
            values: self.values,
            positional: self.positional,
        })
    }
}

/// Parse result with typed getters.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_f32(&self, name: &str) -> Result<f32> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == "true"
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn parser() -> Args {
        Args::new("t", "test")
            .flag("rounds", "70", "rounds")
            .flag("frac", "0.25", "a fraction")
            .switch("quick", "quick mode")
            .required("preset", "preset name")
    }

    #[test]
    fn defaults_and_values() {
        let p = parser()
            .parse(&argv(&["--preset", "eurlex", "--quick"]))
            .unwrap();
        assert_eq!(p.get("preset"), "eurlex");
        assert_eq!(p.get_usize("rounds").unwrap(), 70);
        assert!(p.get_bool("quick"));
        assert_eq!(p.get_f32("frac").unwrap(), 0.25);
        assert!(p.get_f32("preset").is_err(), "non-numeric must error");
    }

    #[test]
    fn equals_syntax_and_positional() {
        let p = parser()
            .parse(&argv(&["--preset=tiny", "--rounds=3", "pos1"]))
            .unwrap();
        assert_eq!(p.get("preset"), "tiny");
        assert_eq!(p.get_usize("rounds").unwrap(), 3);
        assert!(!p.get_bool("quick"));
        assert_eq!(p.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn missing_required_fails() {
        assert!(parser().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_flag_fails() {
        let err = parser()
            .parse(&argv(&["--preset", "x", "--nope"]))
            .unwrap_err();
        assert!(err.to_string().contains("unknown flag"));
    }

    #[test]
    fn help_includes_flags() {
        let err = parser().parse(&argv(&["--help"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--rounds") && msg.contains("(required)"));
    }
}
