//! Seeded randomized property testing (proptest is unavailable offline).
//!
//! [`check`] runs a property over `cases` generated inputs; on failure it
//! reports the case index and the seed that reproduces it, so a failing
//! property is a one-line repro:
//!
//! ```no_run
//! use fedmlh::util::prop::{check, Gen};
//! check("sum is commutative", 64, |g: &mut Gen| {
//!     let a = g.f32_in(-10.0, 10.0);
//!     let b = g.f32_in(-10.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Standalone generator (Monte-Carlo helpers outside [`check`]).
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            case: 0,
        }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Vector of uniform f32s.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Strictly positive probability vector summing to 1.
    pub fn simplex(&mut self, len: usize) -> Vec<f64> {
        let raw: Vec<f64> = (0..len).map(|_| self.rng.next_f64() + 1e-3).collect();
        let total: f64 = raw.iter().sum();
        raw.into_iter().map(|x| x / total).collect()
    }
}

/// Run `prop` over `cases` seeded inputs. Panics (with the reproducing
/// seed) on the first failing case. Honors `FEDMLH_PROP_SEED` to replay.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let base = std::env::var("FEDMLH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xfed_317u64);
    for case in 0..cases {
        let seed = super::rng::derive_seed(base, case as u64);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: FEDMLH_PROP_SEED={base}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("counts", 10, |_g| {
            count += 1;
        });
        assert_eq!(count, 10);
    }

    #[test]
    fn failing_property_reports_case_and_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails", 5, |g| {
                assert!(g.case < 3, "boom at {}", g.case);
            });
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{payload:?}"));
        assert!(msg.contains("failed at case 3"), "{msg}");
        assert!(msg.contains("FEDMLH_PROP_SEED"), "{msg}");
    }

    #[test]
    fn simplex_sums_to_one_and_positive() {
        check("simplex", 20, |g| {
            let len = g.usize_in(1, 50);
            let s = g.simplex(len);
            let total: f64 = s.iter().sum();
            assert!((total - 1.0).abs() < 1e-9);
            assert!(s.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    fn gen_ranges() {
        check("ranges", 50, |g| {
            let u = g.usize_in(3, 9);
            assert!((3..9).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }
}
