//! Dependency-free substrates: RNG, JSON, tensors, CLI, timing,
//! property testing.
//!
//! The offline crate registry only carries the `xla` closure, so the
//! pieces a production service would pull from crates.io (rand, serde,
//! clap, proptest, criterion) are implemented here, small and tested.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tensor;
pub mod timer;
