//! Wall-clock timing helpers for the harness and the bench substrate.
//!
//! When the span tracer is installed (`--trace-out`), every
//! [`Stopwatch::time`] section doubles as a wall-clock trace span, so
//! harness/kernel sections show up on the Perfetto timeline without any
//! extra call sites.

use std::time::Instant;

/// A named stopwatch accumulating multiple timed sections.
#[derive(Debug, Default)]
pub struct Stopwatch {
    sections: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name` (and as a trace span
    /// when tracing is enabled).
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let _span = crate::obs::trace::wall_span(name, 0);
        let t0 = Instant::now();
        let out = f();
        self.sections
            .push((name.to_string(), t0.elapsed().as_secs_f64()));
        out
    }

    /// Add an externally measured duration.
    pub fn record(&mut self, name: &str, seconds: f64) {
        self.sections.push((name.to_string(), seconds));
    }

    /// Total seconds recorded under `name`.
    pub fn total(&self, name: &str) -> f64 {
        self.sections
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, s)| s)
            .sum()
    }

    pub fn sections(&self) -> &[(String, f64)] {
        &self.sections
    }

    /// "name: 1.234s, other: 0.5s" summary, aggregated by name.
    pub fn summary(&self) -> String {
        let mut names: Vec<&str> = Vec::new();
        for (n, _) in &self.sections {
            if !names.contains(&n.as_str()) {
                names.push(n);
            }
        }
        names
            .iter()
            .map(|n| format!("{n}: {:.3}s", self.total(n)))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Measure a closure's wall-clock seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_by_name() {
        let mut sw = Stopwatch::new();
        sw.record("a", 1.0);
        sw.record("b", 0.5);
        sw.record("a", 2.0);
        assert!((sw.total("a") - 3.0).abs() < 1e-12);
        assert!((sw.total("b") - 0.5).abs() < 1e-12);
        assert_eq!(sw.total("missing"), 0.0);
        let s = sw.summary();
        assert!(s.contains("a: 3.000s") && s.contains("b: 0.500s"), "{s}");
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
