//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for the AOT `artifacts/manifest.json` (produced by
//! `python/compile/aot.py`) and for the result files the harness writes
//! under `results/`. Supports the full JSON grammar except `\u` escapes
//! beyond the BMP surrogate pairs (not needed by either producer).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use a BTreeMap so serialization is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn usize_list(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize; `indent` 0 means compact.
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, indent, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent > 0 {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: usize, depth: usize) {
    if indent > 0 {
        out.push('\n');
        for _ in 0..indent * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number '{text}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            self.pos += 4;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint {code}"))?,
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', got {:?}", other.map(|c| c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.expect("c").unwrap(), &Json::Bool(false));
        let arr = v.expect("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].expect("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"t":true}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_pretty(0);
        assert_eq!(Json::parse(&out).unwrap(), v);
        // pretty output also round-trips
        let pretty = v.to_string_pretty(2);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"k": [0, 1, 2]}"#).unwrap();
        assert_eq!(v.expect("k").unwrap().usize_list().unwrap(), vec![0, 1, 2]);
        assert!(v.expect("missing").is_err());
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string_pretty(0)).unwrap(), v);
    }

    #[test]
    fn unicode_content() {
        let v = Json::parse(r#""héllo ⊕""#).unwrap();
        assert_eq!(v, Json::Str("héllo ⊕".into()));
    }
}
