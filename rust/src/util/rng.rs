//! Deterministic pseudo-random generation: splitmix64 seeding +
//! xoshiro256++ streams, plus the samplers the data generator needs
//! (uniform, gaussian, Zipf, shuffles, weighted choice).
//!
//! Everything in the system that draws randomness takes an explicit
//! [`Rng`] (or a seed), so every experiment is bit-reproducible from its
//! config seed — a hard requirement for the paper-reproduction harness.

/// splitmix64: used to expand a user seed into xoshiro state and to
/// derive independent per-component seeds (client id, hash table id…).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Derive a stream-specific seed (e.g. per client) from a root seed.
pub fn derive_seed(root: u64, stream: u64) -> u64 {
    let mut s = root ^ 0xa076_1d64_78bd_642f_u64.wrapping_mul(stream.wrapping_add(1));
    let a = splitmix64(&mut s);
    let b = splitmix64(&mut s);
    a ^ b.rotate_left(17)
}

/// xoshiro256++ — fast, high-quality, 2^256 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps the modulo bias below 2^-64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generation is not a hot path).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > f64::EPSILON {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn gaussian_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// `true` with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// `k` distinct indices from `[0, n)` — the partial Fisher–Yates
    /// draw sequence, computed lazily: the identity array is virtualized
    /// behind a sparse displacement map, so time and memory are O(k)
    /// instead of O(n) while every draw stays bit-identical to the dense
    /// swap loop this replaced. Sampling S clients from a million-client
    /// registry costs S map entries, and existing seeds keep their exact
    /// round-for-round schedules.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        // disp[p] = current occupant of virtual position p (identity
        // where absent). Only positions touched by a swap are stored.
        let mut disp: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(2 * k);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vj = disp.get(&j).copied().unwrap_or(j);
            let vi = disp.get(&i).copied().unwrap_or(i);
            out.push(vj);
            // swap(i, j): position j inherits i's occupant; position i
            // (== out[i]) is never read again since all later j' >= i'.
            disp.insert(j, vi);
        }
        out
    }

    /// Index drawn from an (unnormalized, non-negative) weight vector.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(α) sampler over `{0, .., n-1}` by inverse-CDF on the precomputed
/// normalized weights — the label-frequency law of extreme-classification
/// datasets (paper Fig. 2a: "the distribution of positive instance
/// frequency follows a power law in all the datasets").
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-alpha);
            cdf.push(acc);
        }
        let norm = acc;
        for c in cdf.iter_mut() {
            *c /= norm;
        }
        Zipf { cdf }
    }

    /// Probability mass of rank `i` (0-based; rank 0 is the most frequent).
    pub fn pmf(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // binary search over the CDF
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn derive_seed_changes_with_stream() {
        let s0 = derive_seed(1, 0);
        let s1 = derive_seed(1, 1);
        assert_ne!(s0, s1);
        assert_eq!(derive_seed(1, 5), derive_seed(1, 5));
    }

    #[test]
    fn uniform_below_in_range_and_spread() {
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let v = rng.below(10);
            counts[v] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut rng = Rng::new(9);
        let s = rng.sample_without_replacement(10, 4);
        assert_eq!(s.len(), 4);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 4);
        assert!(t.iter().all(|&i| i < 10));
    }

    #[test]
    #[should_panic]
    fn sample_more_than_population_panics() {
        Rng::new(0).sample_without_replacement(3, 4);
    }

    /// The dense partial Fisher–Yates the lazy version replaced; kept
    /// here as the reference the sparse path must match bit for bit.
    fn dense_reference(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    #[test]
    fn lazy_sampler_matches_dense_fisher_yates() {
        for seed in [0u64, 9, 42, 1234] {
            for (n, k) in [(1, 1), (10, 4), (10, 10), (97, 13), (500, 499)] {
                let lazy = Rng::new(seed).sample_without_replacement(n, k);
                let dense = dense_reference(&mut Rng::new(seed), n, k);
                assert_eq!(lazy, dense, "seed {seed}, sample {k} of {n}");
            }
        }
    }

    #[test]
    fn sampling_huge_population_stays_o_of_k() {
        // 2^40 virtual positions: the dense identity array would need
        // 8 TiB. The lazy sampler must finish instantly in O(k).
        let n = 1usize << 40;
        let s = Rng::new(21).sample_without_replacement(n, 8);
        assert_eq!(s.len(), 8);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 8, "duplicates in {s:?}");
        assert!(t.iter().all(|&i| i < n));
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut rng = Rng::new(13);
        let w = [0.0, 9.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted_choice(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > 8 * counts[2], "{counts:?}");
    }

    #[test]
    fn zipf_is_power_law() {
        let z = Zipf::new(1000, 1.2);
        // pmf ratio between rank 1 and rank 10 ≈ 10^1.2
        let ratio = z.pmf(0) / z.pmf(9);
        assert!((ratio - 10f64.powf(1.2)).abs() / 10f64.powf(1.2) < 0.01);
        let mut rng = Rng::new(17);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        let expect = z.cdf[9];
        let got = head as f64 / n as f64;
        assert!((got - expect).abs() < 0.02, "got {got} expect {expect}");
    }

    #[test]
    fn zipf_pmf_sums_to_one() {
        let z = Zipf::new(257, 0.9);
        let total: f64 = (0..257).map(|i| z.pmf(i)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
