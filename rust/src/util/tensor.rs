//! Row-major f32 tensors for host-side parameter and batch storage.
//!
//! Only what the coordinator needs: shaped storage, elementwise
//! arithmetic for aggregation, and (de)serialization into the flat
//! buffers PJRT consumes. Heavy math lives in the AOT artifacts (L2/L1)
//! or in [`crate::model::mlp`] (the pure-rust mock backend).

use anyhow::{bail, Result};

/// Dense row-major f32 tensor (rank 0, 1 or 2 in practice).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Bytes on the wire / in memory (f32).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 4
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Row slice of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// `self += other * scale` (shape-checked) — the aggregation primitive.
    pub fn axpy(&mut self, other: &Tensor, scale: f32) -> Result<()> {
        if self.shape != other.shape {
            bail!("axpy shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b * scale;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Max |a - b| across elements (numeric cross-checks).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("diff shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape_checks() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.byte_size(), 24);
        assert!(Tensor::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        let s = Tensor::scalar(4.0);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.data(), &[4.0]);
    }

    #[test]
    fn indexing_rows() {
        let mut t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.at2(1, 2), 6.0);
        t.set2(0, 1, 9.0);
        assert_eq!(t.row(0), &[1., 9., 3.]);
        t.row_mut(1)[0] = -4.0;
        assert_eq!(t.at2(1, 0), -4.0);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1., 2., 3.]).unwrap();
        let b = Tensor::from_vec(&[3], vec![10., 10., 10.]).unwrap();
        a.axpy(&b, 0.5).unwrap();
        assert_eq!(a.data(), &[6., 7., 8.]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12., 14., 16.]);
        let c = Tensor::zeros(&[4]);
        assert!(a.axpy(&c, 1.0).is_err());
    }

    #[test]
    fn diff_and_norm() {
        let a = Tensor::from_vec(&[2], vec![3., 4.]).unwrap();
        let b = Tensor::from_vec(&[2], vec![3., 4.5]).unwrap();
        assert!((a.l2_norm() - 5.0).abs() < 1e-6);
        assert!((a.max_abs_diff(&b).unwrap() - 0.5).abs() < 1e-6);
    }
}
