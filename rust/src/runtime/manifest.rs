//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! The manifest records, for every emitted HLO module, the entry
//! signature (input order, dtypes, shapes) and the output layout. The
//! runtime validates every buffer against it before the first execute,
//! so a preset/artifact mismatch fails with a readable error instead of
//! an XLA shape check deep inside PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype '{other}' in manifest"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        }
    }
}

/// One named tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Manifest key, e.g. `eurlex.fedmlh.train`.
    pub key: String,
    /// File name inside the artifact directory.
    pub file: String,
    /// `train` | `predict` | `decode`.
    pub kind: String,
    /// Preset this artifact belongs to.
    pub preset: String,
    /// Entry parameters, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tuple elements, in order.
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactEntry {
    /// Input spec by name (signature sanity checks in the backend).
    pub fn input(&self, name: &str) -> Result<&TensorSpec> {
        self.inputs
            .iter()
            .find(|t| t.name == name)
            .with_context(|| format!("artifact {}: no input '{name}'", self.key))
    }
}

/// The parsed manifest plus the directory it came from.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

fn parse_tensor(j: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: j.expect("name")?.as_str()?.to_string(),
        dtype: Dtype::parse(j.expect("dtype")?.as_str()?)?,
        shape: j.expect("shape")?.usize_list()?,
    })
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (split out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest.json parse error")?;
        let format = root.expect("format")?.as_usize()?;
        if format != 1 {
            bail!("unsupported manifest format {format} (expected 1)");
        }
        let mut artifacts = BTreeMap::new();
        for (key, entry) in root.expect("artifacts")?.as_obj()? {
            let inputs = entry
                .expect("inputs")?
                .as_arr()?
                .iter()
                .map(parse_tensor)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("artifact {key}: bad inputs"))?;
            let outputs = entry
                .expect("outputs")?
                .as_arr()?
                .iter()
                .map(parse_tensor)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("artifact {key}: bad outputs"))?;
            artifacts.insert(
                key.clone(),
                ArtifactEntry {
                    key: key.clone(),
                    file: entry.expect("file")?.as_str()?.to_string(),
                    kind: entry.expect("kind")?.as_str()?.to_string(),
                    preset: entry.expect("preset")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Entry by key, with a helpful error naming near misses.
    pub fn entry(&self, key: &str) -> Result<&ArtifactEntry> {
        if let Some(e) = self.artifacts.get(key) {
            return Ok(e);
        }
        let prefix = key.split('.').next().unwrap_or(key);
        let near: Vec<&str> = self
            .artifacts
            .keys()
            .filter(|k| k.starts_with(prefix))
            .map(|s| s.as_str())
            .collect();
        bail!(
            "artifact '{key}' not in manifest (have for this preset: {}) — \
             re-run `make artifacts` if presets changed",
            if near.is_empty() {
                "none".to_string()
            } else {
                near.join(", ")
            }
        )
    }

    pub fn contains(&self, key: &str) -> bool {
        self.artifacts.contains_key(key)
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.entry(key)?.file))
    }

    /// All keys for one preset (diagnostics, tests).
    pub fn keys_for_preset(&self, preset: &str) -> Vec<&str> {
        self.artifacts
            .values()
            .filter(|e| e.preset == preset)
            .map(|e| e.key.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "presets": {"tiny": {"d": 32}},
      "artifacts": {
        "tiny.fedavg.train": {
          "file": "tiny.fedavg.train.hlo.txt",
          "kind": "train",
          "preset": "tiny",
          "sha256": "x",
          "inputs": [
            {"name": "w1", "dtype": "f32", "shape": [32, 16]},
            {"name": "lr", "dtype": "f32", "shape": []}
          ],
          "outputs": [
            {"name": "loss", "dtype": "f32", "shape": []}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let e = m.entry("tiny.fedavg.train").unwrap();
        assert_eq!(e.kind, "train");
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![32, 16]);
        assert_eq!(e.inputs[0].elements(), 512);
        assert_eq!(e.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(e.input("lr").unwrap().dtype, Dtype::F32);
        assert!(e.input("nope").is_err());
        assert_eq!(
            m.path_of("tiny.fedavg.train").unwrap(),
            PathBuf::from("/tmp/a/tiny.fedavg.train.hlo.txt")
        );
    }

    #[test]
    fn missing_key_lists_preset_artifacts() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let err = m.entry("tiny.fedmlh.train").unwrap_err().to_string();
        assert!(err.contains("tiny.fedavg.train"), "{err}");
        assert!(!m.contains("tiny.fedmlh.train"));
    }

    #[test]
    fn rejects_unknown_format() {
        let bad = SAMPLE.replace("\"format\": 1", "\"format\": 9");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn keys_for_preset_filters() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.keys_for_preset("tiny"), vec!["tiny.fedavg.train"]);
        assert!(m.keys_for_preset("eurlex").is_empty());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Only meaningful after `make artifacts`; skip silently otherwise.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.contains("tiny.fedavg.train"));
            let e = m.entry("tiny.fedmlh.decode").unwrap();
            assert_eq!(e.kind, "decode");
            assert_eq!(e.inputs[1].dtype, Dtype::I32);
        }
    }
}
