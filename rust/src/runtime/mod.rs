//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`)
//! emitted by `python/compile/aot.py` and executes them on the PJRT CPU
//! client. This is the only place the `xla` crate is touched; python is
//! never on the training path.
//!
//! - [`manifest`] — parses `artifacts/manifest.json` (entry signatures,
//!   shapes, hashes) so buffers are validated *before* the first execute.
//!   Pure rust; always compiled.
//! - `client` — a `RuntimeClient`: one `PjRtClient` plus a compile cache
//!   keyed by artifact name (each HLO module is compiled exactly once
//!   per process, then re-executed). Requires the `xla` cargo feature.
//! - `train_exec` — `XlaBackend`, the production
//!   [`crate::federated::backend::TrainBackend`]: the local-training
//!   loop, prediction and count-sketch decode all route through compiled
//!   HLO executables. Requires the `xla` cargo feature.
//!
//! Without the `xla` feature (the default in environments where the
//! `xla` PJRT bindings are not vendored), [`stub`]-provided types with
//! the identical API keep every caller compiling; constructing them
//! fails with an actionable error and the pure-rust backend
//! ([`crate::federated::backend::RustBackend`]) is the training path.

pub mod manifest;

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod train_exec;

#[cfg(not(feature = "xla"))]
pub mod stub;

#[cfg(feature = "xla")]
pub use client::RuntimeClient;
#[cfg(feature = "xla")]
pub use train_exec::XlaBackend;

#[cfg(not(feature = "xla"))]
pub use stub::{RuntimeClient, XlaBackend};

pub use manifest::{ArtifactEntry, Dtype, Manifest, TensorSpec};

/// Default artifact directory, relative to the repo root (where `cargo`
/// runs from). Overridable everywhere via `--artifacts <dir>`.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
