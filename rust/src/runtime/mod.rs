//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`)
//! emitted by `python/compile/aot.py` and executes them on the PJRT CPU
//! client. This is the only place the `xla` crate is touched; python is
//! never on the training path.
//!
//! - [`manifest`] — parses `artifacts/manifest.json` (entry signatures,
//!   shapes, hashes) so buffers are validated *before* the first execute.
//! - [`client`] — a [`client::RuntimeClient`]: one `PjRtClient` plus a
//!   compile cache keyed by artifact name (each HLO module is compiled
//!   exactly once per process, then re-executed).
//! - [`train_exec`] — [`train_exec::XlaBackend`], the production
//!   [`crate::federated::backend::TrainBackend`]: the local-training
//!   loop, prediction and count-sketch decode all route through compiled
//!   HLO executables.

pub mod client;
pub mod manifest;
pub mod train_exec;

pub use client::RuntimeClient;
pub use manifest::{ArtifactEntry, Dtype, Manifest, TensorSpec};
pub use train_exec::XlaBackend;

/// Default artifact directory, relative to the repo root (where `cargo`
/// runs from). Overridable everywhere via `--artifacts <dir>`.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
