//! API-identical stand-ins for the PJRT runtime, compiled when the
//! `xla` cargo feature is **off**.
//!
//! The real `RuntimeClient`/`XlaBackend` (see `runtime::client` and
//! `runtime::train_exec`) bind the external `xla` crate, which is not
//! vendored in offline build environments. These stubs expose the same
//! constructors and methods so the CLI, harness, benches and
//! integration tests compile unchanged.
//!
//! The split of responsibilities mirrors what is actually xla-bound:
//! [`RuntimeClient`] still loads and serves the artifact **manifest**
//! (pure rust — `fedmlh artifacts` keeps working without the feature),
//! while anything that would compile or execute HLO ([`XlaBackend`])
//! fails at construction with an actionable error pointing at
//! `--backend rust` / the missing feature.

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::{Algo, ExperimentConfig};
use crate::federated::backend::{TrainBackend, TrainStats};
use crate::federated::batcher::ClientBatcher;
use crate::model::params::ModelParams;

use super::manifest::Manifest;

const FEATURE_HINT: &str = "this build has no PJRT runtime (compiled without the `xla` cargo \
     feature) — use `--backend rust`, or rebuild with `--features xla` \
     and the xla crate available";

/// Stand-in for the PJRT CPU client: serves the parsed manifest (pure
/// rust), reports no compiled executables and no platform.
#[derive(Debug)]
pub struct RuntimeClient {
    manifest: Manifest,
}

impl RuntimeClient {
    /// Loads `<dir>/manifest.json` exactly like the real client (same
    /// missing-artifact errors); succeeds so manifest-only callers
    /// (e.g. `fedmlh artifacts`) work without the `xla` feature.
    pub fn new(artifact_dir: &Path) -> Result<Rc<Self>> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Rc::new(RuntimeClient { manifest }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        "unavailable (no `xla` feature)".to_string()
    }

    pub fn compiled_count(&self) -> usize {
        0
    }
}

/// Stand-in for the HLO-executing training backend; never constructible
/// (the `Infallible` field is uninhabited).
pub struct XlaBackend {
    _uninhabited: std::convert::Infallible,
}

impl XlaBackend {
    pub fn new(_rt: Rc<RuntimeClient>, _cfg: &ExperimentConfig, _algo: Algo) -> Result<Self> {
        bail!("{FEATURE_HINT}")
    }

    pub fn open(artifact_dir: &Path, cfg: &ExperimentConfig, algo: Algo) -> Result<Self> {
        let rt = RuntimeClient::new(artifact_dir)?;
        Self::new(rt, cfg, algo)
    }

    pub fn hlo_decode(&self) -> bool {
        false
    }
}

impl TrainBackend for XlaBackend {
    fn local_train(
        &self,
        _params: &mut ModelParams,
        _batcher: &mut ClientBatcher<'_>,
        _epochs: usize,
        _lr: f32,
    ) -> Result<TrainStats> {
        bail!("{FEATURE_HINT}")
    }

    fn predict(&self, _params: &ModelParams, _x: &[f32]) -> Result<Vec<f32>> {
        bail!("{FEATURE_HINT}")
    }

    fn decode(
        &self,
        _logits: &[f32],
        _idx: &[i32],
        _r: usize,
        _rows: usize,
        _b: usize,
        _p: usize,
    ) -> Result<Vec<f32>> {
        bail!("{FEATURE_HINT}")
    }

    fn batch_size(&self) -> usize {
        0
    }

    fn name(&self) -> &str {
        "xla-unavailable"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL_MANIFEST: &str = r#"{
      "format": 1,
      "artifacts": {
        "tiny.fedavg.train": {
          "file": "tiny.fedavg.train.hlo.txt",
          "kind": "train",
          "preset": "tiny",
          "inputs": [{"name": "w1", "dtype": "f32", "shape": [32, 16]}],
          "outputs": [{"name": "loss", "dtype": "f32", "shape": []}]
        }
      }
    }"#;

    // Tests run in parallel: `tag` keeps each test's directory private.
    fn temp_artifact_dir(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("fedmlh_stub_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MINIMAL_MANIFEST).unwrap();
        dir
    }

    #[test]
    fn missing_dir_reports_make_artifacts() {
        let err = RuntimeClient::new(Path::new("/nonexistent/artifacts"))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_only_paths_work_without_the_feature() {
        let dir = temp_artifact_dir("manifest_only");
        let rt = RuntimeClient::new(&dir).unwrap();
        assert!(rt.manifest().contains("tiny.fedavg.train"));
        assert_eq!(rt.compiled_count(), 0);
        assert!(rt.platform_name().contains("unavailable"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_construction_names_the_feature() {
        let dir = temp_artifact_dir("backend");
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let rt = RuntimeClient::new(&dir).unwrap();
        let err = XlaBackend::new(rt, &cfg, Algo::FedAvg)
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("xla"), "{err}");
        assert!(err.contains("--backend rust"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
