//! [`XlaBackend`] — the production training backend: every train step,
//! prediction and count-sketch decode is one PJRT execute of an AOT
//! artifact. The whole local-training loop (paper Algorithm 2
//! `DeviceTrain`) runs without touching python.
//!
//! The train-step HLO is `(w1..b3, x, y, lr) → (w1'..b3', loss)` — one
//! fused forward+backward+SGD module, so a local epoch is
//! `batches_per_epoch` executes with the parameters round-tripping
//! through host literals (on the CPU plugin device memory *is* host
//! memory, so this is a memcpy, not a PCIe transfer; see
//! EXPERIMENTS.md §Perf for the measured breakdown).

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::{Algo, ExperimentConfig};
use crate::federated::backend::{TrainBackend, TrainStats};
use crate::federated::batcher::ClientBatcher;
use crate::model::params::{ModelParams, N_PARAMS};

use super::client::RuntimeClient;
use super::manifest::ArtifactEntry;

/// Execute with rust-owned input buffers.
///
/// NOT `exe.execute::<Literal>(..)`: the xla crate's literal path leaks
/// every input's device buffer (the C++ wrapper `release()`s them and
/// never frees after the run — ~3.5 MB/step at eurlex scale, found as
/// a 34 GB OOM after ~25 rounds). `PjRtBuffer`s created on the rust
/// side carry a proper `Drop`, so this path is leak-free (and skips the
/// intermediate `Literal` copy entirely).
fn execute_buffers(
    rt: &RuntimeClient,
    exe: &xla::PjRtLoadedExecutable,
    f32_inputs: &[(&[f32], &[usize])],
    i32_input: Option<(&[i32], &[usize])>,
) -> Result<xla::Literal> {
    let mut bufs = Vec::with_capacity(f32_inputs.len() + 1);
    for (data, dims) in f32_inputs {
        bufs.push(rt.to_device_f32(data, dims)?);
    }
    if let Some((data, dims)) = i32_input {
        bufs.push(rt.to_device_i32(data, dims)?);
    }
    let result = exe.execute_b(&bufs)?[0][0]
        .to_literal_sync()
        .context("device→host")?;
    Ok(result)
}

/// TrainBackend over compiled HLO artifacts.
pub struct XlaBackend {
    rt: Rc<RuntimeClient>,
    train: Rc<xla::PjRtLoadedExecutable>,
    /// Scan-fused train step: S consecutive minibatches per dispatch
    /// (`<tag>.train8`). The perf-pass hot path — removes S−1 of every
    /// S parameter round trips and dispatches (§Perf). `None` when the
    /// manifest predates the scan variants.
    train_scan: Option<(Rc<xla::PjRtLoadedExecutable>, usize)>,
    predict: Rc<xla::PjRtLoadedExecutable>,
    /// `None` when the manifest carries no decode artifact for this
    /// configuration (e.g. FedAvg, or a B×R override combination the
    /// sweep tables don't cover) — decode then falls back to the rust
    /// reference path, which the integration tests pin to the HLO one.
    decode: Option<Rc<xla::PjRtLoadedExecutable>>,
    /// (d, hidden, out) of one model; `batch` baked into the artifacts.
    d: usize,
    hidden: usize,
    out: usize,
    batch: usize,
    /// Decode artifact dims (r, p), when present.
    decode_rp: Option<(usize, usize)>,
    name: String,
}

/// Check a manifest entry's input against expectations.
fn expect_shape(e: &ArtifactEntry, name: &str, want: &[usize]) -> Result<()> {
    let spec = e.input(name)?;
    if spec.shape != want {
        bail!(
            "artifact {}: input '{name}' has shape {:?}, run expects {:?} — \
             preset/config drift; re-run `make artifacts`",
            e.key,
            spec.shape,
            want
        );
    }
    Ok(())
}

impl XlaBackend {
    /// Load (and compile, memoized) the artifacts for `cfg` × `algo`.
    pub fn new(rt: Rc<RuntimeClient>, cfg: &ExperimentConfig, algo: Algo) -> Result<Self> {
        let tag = cfg.artifact_tag(algo);
        let (d, hidden, out, batch) = (
            cfg.preset.d,
            cfg.preset.hidden,
            cfg.out_dim(algo),
            cfg.preset.batch,
        );

        let train_entry = rt.manifest().entry(&format!("{tag}.train"))?.clone();
        expect_shape(&train_entry, "w1", &[d, hidden])?;
        expect_shape(&train_entry, "w3", &[hidden, out])?;
        expect_shape(&train_entry, "x", &[batch, d])?;
        expect_shape(&train_entry, "y", &[batch, out])?;
        if train_entry.inputs.len() != N_PARAMS + 3 {
            bail!(
                "artifact {}: expected {} inputs, manifest lists {}",
                train_entry.key,
                N_PARAMS + 3,
                train_entry.inputs.len()
            );
        }

        let train = rt.load(&train_entry.key)?;
        // Optional scan-fused variant (any `<tag>.trainN` in the manifest).
        let mut train_scan = None;
        for s in [8usize] {
            let key = format!("{tag}.train{s}");
            if rt.manifest().contains(&key) {
                let e = rt.manifest().entry(&key)?;
                let xs = e.input("xs")?;
                if xs.shape == [s, batch, d] {
                    train_scan = Some((rt.load(&key)?, s));
                }
            }
        }
        let predict = rt.load(&format!("{tag}.predict"))?;

        let mut decode = None;
        let mut decode_rp = None;
        if algo == Algo::FedMlh {
            // Figure-5 R sweeps change only the decode artifact's idx rows.
            let decode_key = if cfg.override_r > 0 && cfg.override_r != cfg.preset.r {
                format!("{}.fedmlh_r{}.decode", cfg.preset.name, cfg.override_r)
            } else {
                format!("{tag}.decode")
            };
            if rt.manifest().contains(&decode_key) {
                let e = rt.manifest().entry(&decode_key)?;
                let logits_spec = e.input("logits")?;
                if logits_spec.shape != [cfg.r(), batch, out] {
                    bail!(
                        "decode artifact {decode_key}: logits shape {:?} vs run's [{}, {batch}, {out}]",
                        logits_spec.shape,
                        cfg.r()
                    );
                }
                let p = e.input("idx")?.shape[1];
                decode_rp = Some((cfg.r(), p));
                decode = Some(rt.load(&decode_key)?);
            }
        }

        Ok(XlaBackend {
            rt,
            train,
            train_scan,
            predict,
            decode,
            d,
            hidden,
            out,
            batch,
            decode_rp,
            name: format!("xla:{tag}"),
        })
    }

    /// Convenience: open the default artifact dir and build a backend.
    pub fn open(artifact_dir: &Path, cfg: &ExperimentConfig, algo: Algo) -> Result<Self> {
        let rt = RuntimeClient::new(artifact_dir)?;
        Self::new(rt, cfg, algo)
    }

    /// The runtime (shared compile cache) this backend executes on.
    pub fn runtime(&self) -> &Rc<RuntimeClient> {
        &self.rt
    }

    /// Whether the count-sketch decode runs as compiled HLO (vs the rust
    /// fallback).
    pub fn hlo_decode(&self) -> bool {
        self.decode.is_some()
    }

    fn check_params(&self, params: &ModelParams) -> Result<()> {
        if (params.d, params.hidden, params.out) != (self.d, self.hidden, self.out) {
            bail!(
                "{}: params ({},{},{}) do not match artifact ({},{},{})",
                self.name,
                params.d,
                params.hidden,
                params.out,
                self.d,
                self.hidden,
                self.out
            );
        }
        Ok(())
    }

    /// One fused SGD step; copies updated parameters back into `params`
    /// and returns the pre-update loss.
    pub fn step(&self, params: &mut ModelParams, x: &[f32], y: &[f32], lr: f32) -> Result<f32> {
        self.check_params(params)?;
        let lr_data = [lr];
        let mut inputs: Vec<(&[f32], &[usize])> = params
            .tensors
            .iter()
            .map(|t| (t.data(), t.shape()))
            .collect();
        let x_dims = [self.batch, self.d];
        let y_dims = [self.batch, self.out];
        inputs.push((x, &x_dims));
        inputs.push((y, &y_dims));
        inputs.push((&lr_data, &[]));
        let result = execute_buffers(&self.rt, &self.train, &inputs, None)
            .context("train step")?;
        let outs = result.to_tuple()?;
        if outs.len() != N_PARAMS + 1 {
            bail!(
                "{}: train step returned {}-tuple, expected {}",
                self.name,
                outs.len(),
                N_PARAMS + 1
            );
        }
        for (tensor, lit) in params.tensors.iter_mut().zip(outs.iter()) {
            lit.copy_raw_to::<f32>(tensor.data_mut())
                .context("copying updated params")?;
        }
        let loss = outs[N_PARAMS].get_first_element::<f32>()?;
        Ok(loss)
    }

    /// Fused steps per dispatch (1 when no scan artifact is loaded).
    pub fn scan_steps(&self) -> usize {
        self.train_scan.as_ref().map(|(_, s)| *s).unwrap_or(1)
    }

    /// S fused SGD steps in one dispatch: `xs` flat `[S, batch, d]`,
    /// `ys` flat `[S, batch, out]`. Returns the *sum* of the S losses.
    pub fn step_scan(
        &self,
        params: &mut ModelParams,
        xs: &[f32],
        ys: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let (exe, s) = self
            .train_scan
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{}: no scan artifact loaded", self.name))?;
        self.check_params(params)?;
        debug_assert_eq!(xs.len(), s * self.batch * self.d);
        debug_assert_eq!(ys.len(), s * self.batch * self.out);
        let lr_data = [lr];
        let mut inputs: Vec<(&[f32], &[usize])> = params
            .tensors
            .iter()
            .map(|t| (t.data(), t.shape()))
            .collect();
        let xs_dims = [*s, self.batch, self.d];
        let ys_dims = [*s, self.batch, self.out];
        inputs.push((xs, &xs_dims));
        inputs.push((ys, &ys_dims));
        inputs.push((&lr_data, &[]));
        let result =
            execute_buffers(&self.rt, exe, &inputs, None).context("train scan")?;
        let outs = result.to_tuple()?;
        for (tensor, lit) in params.tensors.iter_mut().zip(outs.iter()) {
            lit.copy_raw_to::<f32>(tensor.data_mut())
                .context("copying updated params (scan)")?;
        }
        Ok(outs[N_PARAMS].get_first_element::<f32>()?)
    }
}

impl TrainBackend for XlaBackend {
    fn local_train(
        &self,
        params: &mut ModelParams,
        batcher: &mut ClientBatcher<'_>,
        epochs: usize,
        lr: f32,
    ) -> Result<TrainStats> {
        if batcher.batch_size() != self.batch {
            bail!(
                "{}: batcher batch {} != artifact batch {}",
                self.name,
                batcher.batch_size(),
                self.batch
            );
        }
        let t0 = std::time::Instant::now();
        let mut steps = 0usize;
        let mut loss_sum = 0.0f64;
        let scan = self.scan_steps();
        // Chunk buffers for the scan path (reused across epochs).
        let mut xs = vec![0.0f32; scan * self.batch * self.d];
        let mut ys = vec![0.0f32; scan * self.batch * self.out];
        let (xlen, ylen) = (self.batch * self.d, self.batch * self.out);
        for epoch in 0..epochs {
            batcher.reset(epoch);
            let mut filled = 0usize;
            if scan > 1 {
                // Stage batches straight into the [S, batch, ·] slabs —
                // no intermediate copy through the batcher's buffers.
                while batcher.next_batch_into(
                    &mut xs[filled * xlen..(filled + 1) * xlen],
                    &mut ys[filled * ylen..(filled + 1) * ylen],
                ) {
                    filled += 1;
                    if filled == scan {
                        loss_sum += self.step_scan(params, &xs, &ys, lr)? as f64;
                        steps += scan;
                        filled = 0;
                    }
                }
            } else {
                while let Some(batch) = batcher.next_batch() {
                    loss_sum += self.step(params, batch.x, batch.y, lr)? as f64;
                    steps += 1;
                }
            }
            // Tail of the epoch: single fused steps.
            for i in 0..filled {
                loss_sum += self.step(
                    params,
                    &xs[i * xlen..(i + 1) * xlen],
                    &ys[i * ylen..(i + 1) * ylen],
                    lr,
                )? as f64;
                steps += 1;
            }
        }
        Ok(TrainStats {
            steps,
            mean_loss: if steps > 0 { loss_sum / steps as f64 } else { 0.0 },
            seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn predict(&self, params: &ModelParams, x: &[f32]) -> Result<Vec<f32>> {
        self.check_params(params)?;
        if x.len() != self.batch * self.d {
            bail!(
                "{}: predict input len {} != batch {} × d {}",
                self.name,
                x.len(),
                self.batch,
                self.d
            );
        }
        let mut inputs: Vec<(&[f32], &[usize])> = params
            .tensors
            .iter()
            .map(|t| (t.data(), t.shape()))
            .collect();
        let x_dims = [self.batch, self.d];
        inputs.push((x, &x_dims));
        let result =
            execute_buffers(&self.rt, &self.predict, &inputs, None).context("predict")?;
        let logits = result.to_tuple1()?;
        Ok(logits.to_vec::<f32>()?)
    }

    fn decode(
        &self,
        logits: &[f32],
        idx: &[i32],
        r: usize,
        rows: usize,
        b: usize,
        p: usize,
    ) -> Result<Vec<f32>> {
        let (exe, (art_r, art_p)) = match (&self.decode, self.decode_rp) {
            (Some(exe), Some(rp)) if rp == (r, p) && b == self.out && rows <= self.batch => {
                (exe, rp)
            }
            // Shape not covered by an artifact → rust reference decode.
            _ => return Ok(crate::eval::decode::sketch_decode(logits, idx, r, rows, b, p)),
        };
        debug_assert_eq!((r, p), (art_r, art_p));
        // Pad [r, rows, b] → [r, batch, b] (the artifact's fixed batch).
        let mut padded = vec![0.0f32; r * self.batch * b];
        for table in 0..r {
            let src = &logits[table * rows * b..(table + 1) * rows * b];
            padded[table * self.batch * b..table * self.batch * b + rows * b]
                .copy_from_slice(src);
        }
        let logits_dims = [r, self.batch, b];
        let idx_dims = [r, p];
        let result = execute_buffers(
            &self.rt,
            exe,
            &[(&padded, &logits_dims)],
            Some((idx, &idx_dims)),
        )
        .context("decode")?;
        let scores = result.to_tuple1()?.to_vec::<f32>()?;
        Ok(scores[..rows * p].to_vec())
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::data::synth::generate_preset;
    use crate::federated::backend::RustBackend;
    use crate::federated::batcher::Target;

    fn artifact_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn available() -> bool {
        artifact_dir().join("manifest.json").exists()
    }

    fn tiny_backend(algo: Algo) -> (ExperimentConfig, XlaBackend) {
        let cfg = ExperimentConfig::preset("tiny").unwrap();
        let be = XlaBackend::open(&artifact_dir(), &cfg, algo).unwrap();
        (cfg, be)
    }

    #[test]
    fn step_matches_rust_reference() {
        if !available() {
            return;
        }
        let (cfg, be) = tiny_backend(Algo::FedAvg);
        let data = generate_preset(&cfg.preset, 7);
        let ds = &data.train;
        let samples: Vec<usize> = (0..64).collect();
        let mut xla_params = ModelParams::init(ds.d(), cfg.preset.hidden, ds.p(), 3);
        let mut rust_params = xla_params.clone();

        let mut batcher =
            ClientBatcher::new(ds, &samples, Target::Classes, cfg.preset.batch, 11);
        batcher.reset(0);
        let rust = RustBackend::new();
        let mut ws = crate::model::mlp::Workspace::new(&rust_params, cfg.preset.batch);
        while let Some(batch) = batcher.next_batch() {
            let l_xla = be.step(&mut xla_params, batch.x, batch.y, cfg.lr).unwrap();
            let l_rust =
                crate::model::mlp::train_step(&mut rust_params, &mut ws, batch.x, batch.y, cfg.lr);
            assert!(
                (l_xla - l_rust).abs() < 1e-4,
                "loss drift: xla {l_xla} vs rust {l_rust}"
            );
        }
        let drift = xla_params.max_abs_diff(&rust_params).unwrap();
        assert!(drift < 1e-4, "param drift after epoch: {drift}");
        let _ = rust;
    }

    #[test]
    fn predict_matches_rust_forward() {
        if !available() {
            return;
        }
        let (cfg, be) = tiny_backend(Algo::FedMlh);
        let params = ModelParams::init(cfg.preset.d, cfg.preset.hidden, cfg.b(), 5);
        let x: Vec<f32> = (0..cfg.preset.batch * cfg.preset.d)
            .map(|i| ((i % 13) as f32 - 6.0) / 6.0)
            .collect();
        let got = be.predict(&params, &x).unwrap();
        let want = crate::model::mlp::forward(&params, &x, cfg.preset.batch);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn hlo_decode_matches_rust_decode() {
        if !available() {
            return;
        }
        let (cfg, be) = tiny_backend(Algo::FedMlh);
        assert!(be.hlo_decode());
        let (r, b, p) = (cfg.r(), cfg.b(), cfg.preset.p);
        let rows = cfg.preset.batch - 3; // deliberately partial
        let logits: Vec<f32> = (0..r * rows * b).map(|i| (i as f32).sin()).collect();
        let hasher = crate::hashing::label_hash::LabelHasher::new(1, r, p, b);
        let idx = hasher.index_matrix_i32();
        let got = be.decode(&logits, &idx, r, rows, b, p).unwrap();
        let want = crate::eval::decode::sketch_decode(&logits, &idx, r, rows, b, p);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        if !available() {
            return;
        }
        let (_cfg, be) = tiny_backend(Algo::FedAvg);
        let mut wrong = ModelParams::init(8, 4, 10, 1);
        let err = be.step(&mut wrong, &[0.0; 8], &[0.0; 10], 0.1).unwrap_err();
        assert!(err.to_string().contains("do not match artifact"));
    }
}
