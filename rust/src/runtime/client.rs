//! The PJRT CPU client plus a compile cache.
//!
//! Compiling an HLO module is the expensive part (XLA optimization
//! pipeline); executing it is cheap. [`RuntimeClient`] therefore keeps
//! one `PjRtClient` for the process and memoizes
//! `HloModuleProto::from_text_file → compile` per artifact key, so each
//! model variant is compiled exactly once no matter how many federated
//! clients/rounds execute it (FedMLH's R sub-models share one artifact —
//! identical shapes — so R federated streams cost one compile).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::manifest::Manifest;

/// A loaded PJRT CPU client with its artifact manifest and compile cache.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl RuntimeClient {
    /// Create the PJRT CPU client and load `<dir>/manifest.json`.
    pub fn new(artifact_dir: &Path) -> Result<Rc<Self>> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client init failed")?;
        Ok(Rc::new(RuntimeClient {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (memoized). HLO **text** is the
    /// interchange format: jax ≥ 0.5 emits protos with 64-bit
    /// instruction ids which xla_extension 0.5.1 rejects; the text
    /// parser reassigns ids (see DESIGN.md §2 and aot.py).
    pub fn load(&self, key: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path_of(key)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("XLA compile of {key}"))?,
        );
        self.cache
            .borrow_mut()
            .insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables held in the cache.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Host → device transfer of an f32 tensor.
    pub fn to_device_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("host→device f32 transfer")
    }

    /// Host → device transfer of an i32 tensor.
    pub fn to_device_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("host→device i32 transfer")
    }
}

impl std::fmt::Debug for RuntimeClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RuntimeClient")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.compiled_count())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Guard: these tests only run after `make artifacts`.
    fn available() -> bool {
        artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn load_compile_and_cache() {
        if !available() {
            return;
        }
        let rt = RuntimeClient::new(&artifact_dir()).unwrap();
        assert_eq!(rt.compiled_count(), 0);
        let a = rt.load("tiny.fedavg.predict").unwrap();
        assert_eq!(rt.compiled_count(), 1);
        let b = rt.load("tiny.fedavg.predict").unwrap();
        assert_eq!(rt.compiled_count(), 1, "second load must hit the cache");
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_artifact_fails_with_context() {
        if !available() {
            return;
        }
        let rt = RuntimeClient::new(&artifact_dir()).unwrap();
        let err = match rt.load("tiny.nonexistent.train") {
            Ok(_) => panic!("load of unknown artifact must fail"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("not in manifest"), "{err}");
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = RuntimeClient::new(Path::new("/nonexistent/artifacts"))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
