//! Synthetic extreme multi-label generator (the offline stand-in for the
//! XC-repository datasets — DESIGN.md §3 documents the substitution).
//!
//! Construction, per preset:
//!
//! 1. **Label law**: class frequencies follow Zipf(α) (paper Fig. 2a:
//!    "the distribution of positive instance frequency follows a power
//!    law in all the datasets"). Each sample draws `k ~ 1 + Poisson-ish`
//!    positive classes from the Zipf law (deduplicated), so infrequent
//!    classes still carry a large share of the positive mass (Fig. 2b).
//! 2. **Class prototypes**: every class gets a sparse signature in a raw
//!    feature space of dimension `raw_dim` (a handful of indices with
//!    gaussian weights) — the analog of the bag-of-words features of
//!    EURLex/Wikipedia/Amazon titles.
//! 3. **Samples**: raw features = sum of the prototypes of the sample's
//!    positive classes + sparse background noise, then **feature-hashed**
//!    to d̃ through [`super::feature_hash`], exactly as the paper hashes
//!    its real features.
//!
//! The task is learnable (features determine labels up to noise), so
//! FedMLH-vs-FedAvg accuracy orderings are meaningful, while the label
//! statistics reproduce the regime the paper's Lemma 1 / Theorem 2
//! analysis targets.

use crate::config::DatasetPreset;
use crate::util::rng::{derive_seed, Rng, Zipf};

use super::dataset::Dataset;
use super::feature_hash::FeatureHasher;

/// Generator parameters (derived from a preset, overridable for tests).
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub d: usize,
    pub p: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub zipf_alpha: f64,
    pub labels_per_sample: f64,
    /// Raw (pre-hash) feature dimension.
    pub raw_dim: usize,
    /// Non-zero raw indices per class prototype.
    pub proto_nnz: usize,
    /// Background-noise raw indices per sample.
    pub noise_nnz: usize,
    /// Noise amplitude relative to prototype weights.
    pub noise_scale: f32,
}

impl SynthSpec {
    pub fn from_preset(p: &DatasetPreset) -> Self {
        SynthSpec {
            d: p.d,
            p: p.p,
            n_train: p.n_train,
            n_test: p.n_test,
            zipf_alpha: p.zipf_alpha,
            labels_per_sample: p.labels_per_sample,
            raw_dim: 4 * p.d,
            proto_nnz: 12,
            noise_nnz: 8,
            noise_scale: 0.3,
        }
    }
}

/// Sparse class prototypes in the raw feature space.
struct Prototypes {
    /// (index, weight) lists, one per class.
    rows: Vec<Vec<(u32, f32)>>,
}

impl Prototypes {
    fn generate(spec: &SynthSpec, rng: &mut Rng) -> Self {
        let rows = (0..spec.p)
            .map(|_| {
                (0..spec.proto_nnz)
                    .map(|_| {
                        (
                            rng.below(spec.raw_dim) as u32,
                            rng.gaussian_f32(0.0, 1.0),
                        )
                    })
                    .collect()
            })
            .collect();
        Prototypes { rows }
    }
}

/// Seed-derivation stream for the shared feature-hash function. Shared
/// with the serving checkpoint ([`crate::serve::checkpoint`]) so a
/// server can hash raw sparse inputs exactly like the training data.
pub const FEATURE_HASH_STREAM: u64 = 0x5f_02;

/// The [`FeatureHasher`] seed a world with root seed `root_seed` uses.
pub fn feature_hash_seed(root_seed: u64) -> u64 {
    derive_seed(root_seed, FEATURE_HASH_STREAM)
}

/// Generated train/test pair.
pub struct SynthData {
    pub train: Dataset,
    pub test: Dataset,
}

/// Draw one sample's positive label set from the Zipf law.
fn draw_labels(spec: &SynthSpec, zipf: &Zipf, rng: &mut Rng) -> Vec<u32> {
    // 1 + geometric-ish count with mean ≈ labels_per_sample.
    let extra = spec.labels_per_sample - 1.0;
    let mut k = 1;
    while (k as f64) < 1.0 + 4.0 * extra && rng.bernoulli(extra / (extra + 1.0)) {
        k += 1;
    }
    let mut labels: Vec<u32> = Vec::with_capacity(k);
    for _ in 0..k {
        let c = zipf.sample(rng) as u32;
        if !labels.contains(&c) {
            labels.push(c);
        }
    }
    labels
}

fn make_sample(
    spec: &SynthSpec,
    protos: &Prototypes,
    hasher: &FeatureHasher,
    zipf: &Zipf,
    rng: &mut Rng,
) -> (Vec<f32>, Vec<u32>) {
    let labels = draw_labels(spec, zipf, rng);
    let mut out = vec![0.0f32; spec.d];
    for &c in &labels {
        hasher.hash_into(&protos.rows[c as usize], &mut out);
    }
    // background noise
    let noise: Vec<(u32, f32)> = (0..spec.noise_nnz)
        .map(|_| {
            (
                rng.below(spec.raw_dim) as u32,
                rng.gaussian_f32(0.0, spec.noise_scale),
            )
        })
        .collect();
    hasher.hash_into(&noise, &mut out);
    (out, labels)
}

/// Generate the full train/test pair for `spec`, deterministically from
/// `seed`. Prototypes and the feature-hash function are shared between
/// the splits (same "world"), sample draws are independent.
pub fn generate(spec: &SynthSpec, seed: u64) -> SynthData {
    let mut proto_rng = Rng::new(derive_seed(seed, 0x5f_01));
    let protos = Prototypes::generate(spec, &mut proto_rng);
    let hasher = FeatureHasher::new(feature_hash_seed(seed), spec.d);
    let zipf = Zipf::new(spec.p, spec.zipf_alpha);

    let gen_split = |n: usize, stream: u64| {
        let mut rng = Rng::new(derive_seed(seed, stream));
        let mut ds = Dataset::new(spec.d, spec.p);
        for _ in 0..n {
            let (x, y) = make_sample(spec, &protos, &hasher, &zipf, &mut rng);
            ds.push(&x, &y).unwrap();
        }
        ds
    };

    SynthData {
        train: gen_split(spec.n_train, 0x5f_10),
        test: gen_split(spec.n_test, 0x5f_20),
    }
}

/// Generate from a preset with its default spec.
pub fn generate_preset(preset: &DatasetPreset, seed: u64) -> SynthData {
    generate(&SynthSpec::from_preset(preset), seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;

    fn tiny_spec() -> SynthSpec {
        let mut s = SynthSpec::from_preset(&by_name("tiny").unwrap());
        s.n_train = 400;
        s.n_test = 100;
        s
    }

    #[test]
    fn deterministic_generation() {
        let spec = tiny_spec();
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a.train.features_of(3), b.train.features_of(3));
        assert_eq!(a.train.labels_of(3), b.train.labels_of(3));
        let c = generate(&spec, 8);
        assert_ne!(a.train.features_of(3), c.train.features_of(3));
    }

    #[test]
    fn sizes_and_label_sanity() {
        let spec = tiny_spec();
        let data = generate(&spec, 1);
        assert_eq!(data.train.len(), 400);
        assert_eq!(data.test.len(), 100);
        for i in 0..data.train.len() {
            let labels = data.train.labels_of(i);
            assert!(!labels.is_empty(), "every sample has >=1 positive");
            let mut sorted = labels.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), labels.len(), "no duplicate labels");
        }
    }

    #[test]
    fn label_frequencies_follow_power_law() {
        let spec = tiny_spec();
        let data = generate(&spec, 3);
        let mut counts = data.train.class_counts();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Head class much heavier than the median class.
        let head = counts[0];
        let median = counts[counts.len() / 2];
        assert!(head >= 8 * median.max(1), "head {head} median {median}");
    }

    #[test]
    fn mean_labels_per_sample_near_spec() {
        let mut spec = tiny_spec();
        spec.n_train = 2000;
        spec.labels_per_sample = 3.0;
        let data = generate(&spec, 5);
        let mean = data.train.total_positives() as f64 / data.train.len() as f64;
        // Dedup against Zipf reduces the mean a bit; wide tolerance.
        assert!((1.5..4.5).contains(&mean), "mean labels {mean}");
    }

    #[test]
    fn features_are_informative() {
        // Samples sharing a class should correlate more than random pairs.
        let spec = tiny_spec();
        let data = generate(&spec, 11);
        let ds = &data.train;
        let cos = |a: &[f32], b: &[f32]| {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            dot / (na * nb + 1e-9)
        };
        // find two samples sharing their first label, and two not sharing
        let mut same = Vec::new();
        let mut diff = Vec::new();
        'outer: for i in 0..ds.len() {
            for j in (i + 1)..ds.len() {
                let share = ds.labels_of(i).iter().any(|l| ds.labels_of(j).contains(l));
                let c = cos(ds.features_of(i), ds.features_of(j));
                if share {
                    same.push(c);
                } else {
                    diff.push(c);
                }
                if same.len() > 200 && diff.len() > 200 {
                    break 'outer;
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(
            mean(&same) > mean(&diff) + 0.05,
            "shared-label cosine {} vs {}",
            mean(&same),
            mean(&diff)
        );
    }
}
