//! Reader for the Extreme Classification repository data format
//! (Bhatia et al.), so the paper's real datasets drop in when available:
//!
//! ```text
//! <num_samples> <num_features> <num_labels>
//! l1,l2,...  f1:v1 f2:v2 ...
//! ```
//!
//! Samples may have zero labels; feature indices are 0-based sparse
//! `idx:value` pairs. Features are routed through
//! [`super::feature_hash::FeatureHasher`] to d̃, matching the paper's
//! preprocessing ("we also perform feature hashing to all the datasets").

use anyhow::{anyhow, bail, Context, Result};

use super::dataset::Dataset;
use super::feature_hash::FeatureHasher;

/// Parse XC-format text into a feature-hashed [`Dataset`].
pub fn parse_xc(text: &str, d_out: usize, hash_seed: u64) -> Result<Dataset> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| anyhow!("empty XC file"))?;
    let mut head = header.split_whitespace();
    let n: usize = head
        .next()
        .ok_or_else(|| anyhow!("bad header"))?
        .parse()
        .context("num_samples")?;
    let _d_raw: usize = head
        .next()
        .ok_or_else(|| anyhow!("bad header"))?
        .parse()
        .context("num_features")?;
    let p: usize = head
        .next()
        .ok_or_else(|| anyhow!("bad header"))?
        .parse()
        .context("num_labels")?;

    let hasher = FeatureHasher::new(hash_seed, d_out);
    let mut ds = Dataset::new(d_out, p);
    let mut sparse: Vec<(u32, f32)> = Vec::new();

    for (lineno, line) in lines.enumerate() {
        let line = line.trim();
        // Label block is everything before the first space (may be empty
        // for unlabeled rows that start with a space).
        let (label_part, feat_part) = match line.split_once(' ') {
            Some((l, f)) => (l, f),
            None => (line, ""),
        };
        let mut labels: Vec<u32> = Vec::new();
        if !label_part.is_empty() && !label_part.contains(':') {
            for tok in label_part.split(',') {
                if tok.is_empty() {
                    continue;
                }
                let l: u32 = tok
                    .parse()
                    .with_context(|| format!("line {}: label '{tok}'", lineno + 2))?;
                labels.push(l);
            }
        }
        sparse.clear();
        let feats = if label_part.contains(':') {
            // row had no label block at all
            line
        } else {
            feat_part
        };
        for tok in feats.split_whitespace() {
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| anyhow!("line {}: bad pair '{tok}'", lineno + 2))?;
            sparse.push((
                i.parse().with_context(|| format!("line {}", lineno + 2))?,
                v.parse().with_context(|| format!("line {}", lineno + 2))?,
            ));
        }
        ds.push(&hasher.hash(&sparse), &labels)?;
    }

    if ds.len() != n {
        bail!("header says {n} samples, file has {}", ds.len());
    }
    Ok(ds)
}

/// Load an XC-format file from disk.
pub fn load_xc(path: &std::path::Path, d_out: usize, hash_seed: u64) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_xc(&text, d_out, hash_seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
3 10000 50
1,4 0:1.5 17:2.0 900:0.5
7 3:1.0
0,2,49 5:0.25 9999:1.0
";

    #[test]
    fn parses_counts_and_labels() {
        let ds = parse_xc(SAMPLE, 16, 1).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.d(), 16);
        assert_eq!(ds.p(), 50);
        assert_eq!(ds.labels_of(0), &[1, 4]);
        assert_eq!(ds.labels_of(1), &[7]);
        assert_eq!(ds.labels_of(2), &[0, 2, 49]);
    }

    #[test]
    fn features_are_hashed_consistently() {
        let ds = parse_xc(SAMPLE, 16, 1).unwrap();
        let hasher = FeatureHasher::new(1, 16);
        let want = hasher.hash(&[(0, 1.5), (17, 2.0), (900, 0.5)]);
        assert_eq!(ds.features_of(0), &want[..]);
    }

    #[test]
    fn unlabeled_row_with_colon_start() {
        let text = "1 100 5\n3:1.0 4:2.0\n";
        let ds = parse_xc(text, 8, 0).unwrap();
        assert_eq!(ds.labels_of(0), &[] as &[u32]);
        let hasher = FeatureHasher::new(0, 8);
        assert_eq!(ds.features_of(0), &hasher.hash(&[(3, 1.0), (4, 2.0)])[..]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_xc("", 8, 0).is_err());
        assert!(parse_xc("2 10 5\n0 1:1.0\n", 8, 0).is_err()); // count mismatch
        assert!(parse_xc("1 10 5\n0 1-1.0\n", 8, 0).is_err()); // bad pair
        assert!(parse_xc("1 10 5\n99 1:1.0\n", 8, 0).is_err()); // label >= p
    }
}
