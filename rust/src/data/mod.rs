//! Datasets: synthetic extreme multi-label generation, feature hashing,
//! the XC-repository file format, and label-frequency statistics.
//!
//! The paper evaluates on four public XC datasets we cannot download in
//! this offline environment; [`synth`] generates scaled analogs that
//! preserve the properties the paper's analysis rests on (power-law
//! label frequencies, heavy infrequent-class positive mass, learnable
//! feature→label structure). [`xc_format`] reads the XC repository's
//! sparse format so the real datasets drop in unchanged when available.

pub mod dataset;
pub mod feature_hash;
pub mod stats;
pub mod synth;
pub mod xc_format;

pub use dataset::Dataset;
pub use synth::SynthSpec;
