//! Label-frequency statistics: the series behind paper Figure 2a/2b and
//! the frequent/infrequent class split used by the partitioner (Fig. 2c)
//! and the per-group accuracy metrics (Fig. 3).

use super::dataset::Dataset;

/// Per-class positive counts plus derived series.
#[derive(Clone, Debug)]
pub struct LabelStats {
    /// n_j: positive instances per class.
    pub counts: Vec<usize>,
    /// Sample count the stats were computed over.
    pub n_samples: usize,
}

/// One (x, y) point of a CDF-style curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CurvePoint {
    pub x: f64,
    pub y: f64,
}

impl LabelStats {
    pub fn from_dataset(ds: &Dataset) -> Self {
        LabelStats {
            counts: ds.class_counts(),
            n_samples: ds.len(),
        }
    }

    /// Total positive instances N_lab.
    pub fn total_positives(&self) -> usize {
        self.counts.iter().sum()
    }

    /// Normalized label frequency per class (n_j / N samples).
    pub fn normalized_freq(&self) -> Vec<f64> {
        let n = self.n_samples.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / n).collect()
    }

    /// Figure 2a: empirical CDF of normalized positive-instance
    /// frequency, evaluated at `grid` (x = freq threshold, y = fraction
    /// of classes at or below it).
    pub fn freq_cdf(&self, grid: &[f64]) -> Vec<CurvePoint> {
        let freqs = self.normalized_freq();
        let p = freqs.len().max(1) as f64;
        grid.iter()
            .map(|&x| CurvePoint {
                x,
                y: freqs.iter().filter(|&&f| f <= x).count() as f64 / p,
            })
            .collect()
    }

    /// Figure 2b: share of all positive instances contributed by classes
    /// with normalized frequency ≤ x.
    pub fn positive_mass_cdf(&self, grid: &[f64]) -> Vec<CurvePoint> {
        let n = self.n_samples.max(1) as f64;
        let total = self.total_positives().max(1) as f64;
        grid.iter()
            .map(|&x| {
                let mass: usize = self
                    .counts
                    .iter()
                    .filter(|&&c| c as f64 / n <= x)
                    .sum();
                CurvePoint {
                    x,
                    y: mass as f64 / total,
                }
            })
            .collect()
    }

    /// The `k` most frequent classes, ordered by descending count
    /// (ties broken by class id for determinism).
    pub fn top_k_classes(&self, k: usize) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.counts.len() as u32).collect();
        order.sort_by(|&a, &b| {
            self.counts[b as usize]
                .cmp(&self.counts[a as usize])
                .then(a.cmp(&b))
        });
        order.truncate(k);
        order
    }

    /// Boolean mask: is class `j` frequent (member of the top-k)?
    pub fn frequent_mask(&self, k: usize) -> Vec<bool> {
        let mut mask = vec![false; self.counts.len()];
        for c in self.top_k_classes(k) {
            mask[c as usize] = true;
        }
        mask
    }

    /// Standard log-spaced grid for the Fig 2 curves.
    pub fn log_grid() -> Vec<f64> {
        let mut grid = Vec::new();
        let mut x = 1e-5;
        while x <= 1.0 + 1e-12 {
            grid.push(x);
            x *= 10f64.powf(0.25);
        }
        grid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds_with_counts() -> Dataset {
        // class 0: 4 positives, class 1: 2, class 2: 1, class 3: 0
        let mut ds = Dataset::new(1, 4);
        ds.push(&[0.0], &[0, 1]).unwrap();
        ds.push(&[0.0], &[0]).unwrap();
        ds.push(&[0.0], &[0, 1, 2]).unwrap();
        ds.push(&[0.0], &[0]).unwrap();
        ds
    }

    #[test]
    fn counts_and_totals() {
        let st = LabelStats::from_dataset(&ds_with_counts());
        assert_eq!(st.counts, vec![4, 2, 1, 0]);
        assert_eq!(st.total_positives(), 7);
        assert_eq!(st.n_samples, 4);
    }

    #[test]
    fn freq_cdf_monotone_and_bounded() {
        let st = LabelStats::from_dataset(&ds_with_counts());
        let grid = [0.0, 0.3, 0.6, 1.0];
        let cdf = st.freq_cdf(&grid);
        // class freqs: 1.0, 0.5, 0.25, 0.0
        assert_eq!(cdf[0].y, 0.25); // only class 3 at freq 0
        assert_eq!(cdf[1].y, 0.5); // + class 2 (0.25)
        assert_eq!(cdf[2].y, 0.75); // + class 1 (0.5)
        assert_eq!(cdf[3].y, 1.0);
        for w in cdf.windows(2) {
            assert!(w[1].y >= w[0].y);
        }
    }

    #[test]
    fn positive_mass_cdf_matches_hand_count() {
        let st = LabelStats::from_dataset(&ds_with_counts());
        let pts = st.positive_mass_cdf(&[0.3, 1.0]);
        // classes with freq <= 0.3: class 2 (1) and class 3 (0) → 1/7
        assert!((pts[0].y - 1.0 / 7.0).abs() < 1e-12);
        assert!((pts[1].y - 1.0).abs() < 1e-12);
    }

    #[test]
    fn top_k_ordering_deterministic() {
        let st = LabelStats::from_dataset(&ds_with_counts());
        assert_eq!(st.top_k_classes(2), vec![0, 1]);
        assert_eq!(st.top_k_classes(10), vec![0, 1, 2, 3]);
        let mask = st.frequent_mask(2);
        assert_eq!(mask, vec![true, true, false, false]);
    }

    #[test]
    fn log_grid_spans_decades() {
        let g = LabelStats::log_grid();
        assert!(g[0] <= 1e-5 * 1.01 && *g.last().unwrap() <= 1.0);
        assert!(g.len() > 15);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
