//! Signed feature hashing: sparse high-dimensional inputs → dense d̃.
//!
//! The paper (Section 6): "Since the input features are sparse for most
//! of the extreme classification datasets, feature hashing is widely
//! used to reduce the memory cost. Here, we also use feature hashing to
//! reduce the feature dimension." Both the synthetic generator and the
//! XC-format loader route raw sparse features through this map.
//!
//! `x̃[h(i)] += s(i) · v_i` with `h` 2-universal into d̃ and `s` a ±1
//! sign hash (the sign keeps the map an ℓ2-isometry in expectation).

use crate::util::rng::{derive_seed, Rng};

use super::super::hashing::universal::UniversalHash;

/// A seeded feature-hashing projection raw-dim → d̃.
#[derive(Clone, Debug)]
pub struct FeatureHasher {
    h: UniversalHash,
    d_out: usize,
}

impl FeatureHasher {
    pub fn new(seed: u64, d_out: usize) -> Self {
        let mut rng = Rng::new(derive_seed(seed, 0xfea_7));
        FeatureHasher {
            h: UniversalHash::draw(&mut rng, d_out),
            d_out,
        }
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Hash a sparse vector `(index, value)` into `out` (accumulating;
    /// caller zeroes `out` first if needed).
    pub fn hash_into(&self, sparse: &[(u32, f32)], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d_out);
        for &(i, v) in sparse {
            out[self.h.hash(i as u64)] += self.h.sign(i as u64) * v;
        }
    }

    /// Convenience: allocate and hash.
    pub fn hash(&self, sparse: &[(u32, f32)]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d_out];
        self.hash_into(sparse, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn deterministic() {
        let a = FeatureHasher::new(5, 16);
        let b = FeatureHasher::new(5, 16);
        let sparse = [(0u32, 1.0f32), (100, -2.0), (5000, 0.5)];
        assert_eq!(a.hash(&sparse), b.hash(&sparse));
    }

    #[test]
    fn linear_in_values() {
        check("feature hash linear", 20, |g| {
            let fh = FeatureHasher::new(g.rng().next_u64(), g.usize_in(4, 64));
            let n = g.usize_in(1, 30);
            let xs: Vec<(u32, f32)> = (0..n)
                .map(|_| (g.usize_in(0, 10_000) as u32, g.f32_in(-2.0, 2.0)))
                .collect();
            let ys: Vec<(u32, f32)> = xs.iter().map(|&(i, v)| (i, 2.0 * v)).collect();
            let hx = fh.hash(&xs);
            let hy = fh.hash(&ys);
            for (a, b) in hx.iter().zip(hy.iter()) {
                assert!((2.0 * a - b).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn norm_preserved_in_expectation() {
        // Average ratio ‖x̃‖²/‖x‖² over many draws ≈ 1 (the sign hash
        // cancels cross terms in expectation).
        let mut ratio_sum = 0.0f64;
        let trials = 200;
        let mut rng = Rng::new(1234);
        for t in 0..trials {
            let fh = FeatureHasher::new(t as u64, 64);
            let sparse: Vec<(u32, f32)> = (0..40)
                .map(|_| (rng.below(100_000) as u32, rng.gaussian_f32(0.0, 1.0)))
                .collect();
            let nx: f32 = sparse.iter().map(|(_, v)| v * v).sum();
            let hx = fh.hash(&sparse);
            let nh: f32 = hx.iter().map(|v| v * v).sum();
            ratio_sum += (nh / nx) as f64;
        }
        let mean_ratio = ratio_sum / trials as f64;
        assert!((mean_ratio - 1.0).abs() < 0.15, "mean ratio {mean_ratio}");
    }

    #[test]
    fn accumulates_into_existing_buffer() {
        let fh = FeatureHasher::new(9, 8);
        let mut buf = vec![1.0f32; 8];
        fh.hash_into(&[(3, 2.0)], &mut buf);
        let fresh = fh.hash(&[(3, 2.0)]);
        for i in 0..8 {
            assert!((buf[i] - 1.0 - fresh[i]).abs() < 1e-6);
        }
    }
}
