//! In-memory extreme multi-label dataset: dense (feature-hashed) inputs
//! plus CSR-style sparse positive-label lists.

use anyhow::{bail, Result};

/// A multi-label dataset with dense f32 features and sparse labels.
///
/// Features are stored post-feature-hashing (dimension `d`), matching
/// the paper's Section 6 setup where "both baselines are run on the
/// feature hashed data". Labels are positive-class id lists per sample.
#[derive(Clone, Debug)]
pub struct Dataset {
    d: usize,
    p: usize,
    /// Row-major `[n, d]` features.
    features: Vec<f32>,
    /// CSR offsets into `label_data`, length n+1.
    label_offsets: Vec<usize>,
    label_data: Vec<u32>,
}

impl Dataset {
    pub fn new(d: usize, p: usize) -> Self {
        Dataset {
            d,
            p,
            features: Vec::new(),
            label_offsets: vec![0],
            label_data: Vec::new(),
        }
    }

    /// Append one sample. `labels` must be sorted-or-not positive ids < p.
    pub fn push(&mut self, features: &[f32], labels: &[u32]) -> Result<()> {
        if features.len() != self.d {
            bail!("feature dim {} != {}", features.len(), self.d);
        }
        if let Some(&bad) = labels.iter().find(|&&l| l as usize >= self.p) {
            bail!("label {bad} out of range p={}", self.p);
        }
        self.features.extend_from_slice(features);
        self.label_data.extend_from_slice(labels);
        self.label_offsets.push(self.label_data.len());
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.label_offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn features_of(&self, i: usize) -> &[f32] {
        &self.features[i * self.d..(i + 1) * self.d]
    }

    pub fn labels_of(&self, i: usize) -> &[u32] {
        &self.label_data[self.label_offsets[i]..self.label_offsets[i + 1]]
    }

    /// Total number of positive instances N_lab = Σ_j n_j.
    pub fn total_positives(&self) -> usize {
        self.label_data.len()
    }

    /// Positive-instance count per class (n_j in the paper).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.p];
        for &l in &self.label_data {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Gather a padded feature batch: rows `idx`, zero-padded to
    /// `batch` rows. Returns (flat `[batch, d]`, real row count).
    pub fn feature_batch(&self, idx: &[usize], batch: usize) -> (Vec<f32>, usize) {
        assert!(idx.len() <= batch);
        let mut out = vec![0.0f32; batch * self.d];
        for (row, &i) in idx.iter().enumerate() {
            out[row * self.d..(row + 1) * self.d].copy_from_slice(self.features_of(i));
        }
        (out, idx.len())
    }

    /// Dense multi-hot class label batch `[batch, p]` (FedAvg target).
    pub fn class_label_batch(&self, idx: &[usize], batch: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; batch * self.p];
        for (row, &i) in idx.iter().enumerate() {
            for &l in self.labels_of(i) {
                out[row * self.p + l as usize] = 1.0;
            }
        }
        out
    }

    /// Restrict to a subset of sample indices (client shard view).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut out = Dataset::new(self.d, self.p);
        for &i in idx {
            out.push(self.features_of(i), self.labels_of(i)).unwrap();
        }
        out
    }
}

/// Iterate minibatch index ranges over `n` samples (last batch short).
pub fn batch_ranges(n: usize, batch: usize) -> Vec<(usize, usize)> {
    assert!(batch > 0);
    let mut out = Vec::new();
    let mut start = 0;
    while start < n {
        out.push((start, (start + batch).min(n)));
        start += batch;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_ds() -> Dataset {
        let mut ds = Dataset::new(3, 10);
        ds.push(&[1.0, 2.0, 3.0], &[0, 5]).unwrap();
        ds.push(&[4.0, 5.0, 6.0], &[9]).unwrap();
        ds.push(&[7.0, 8.0, 9.0], &[]).unwrap();
        ds
    }

    #[test]
    fn push_and_access() {
        let ds = sample_ds();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.features_of(1), &[4.0, 5.0, 6.0]);
        assert_eq!(ds.labels_of(0), &[0, 5]);
        assert_eq!(ds.labels_of(2), &[] as &[u32]);
        assert_eq!(ds.total_positives(), 3);
    }

    #[test]
    fn rejects_bad_input() {
        let mut ds = Dataset::new(3, 10);
        assert!(ds.push(&[1.0], &[0]).is_err());
        assert!(ds.push(&[1.0, 2.0, 3.0], &[10]).is_err());
    }

    #[test]
    fn class_counts_match() {
        let ds = sample_ds();
        let counts = ds.class_counts();
        assert_eq!(counts[0], 1);
        assert_eq!(counts[5], 1);
        assert_eq!(counts[9], 1);
        assert_eq!(counts.iter().sum::<usize>(), 3);
    }

    #[test]
    fn feature_batch_pads_with_zeros() {
        let ds = sample_ds();
        let (batch, real) = ds.feature_batch(&[2, 0], 4);
        assert_eq!(real, 2);
        assert_eq!(&batch[0..3], &[7.0, 8.0, 9.0]);
        assert_eq!(&batch[3..6], &[1.0, 2.0, 3.0]);
        assert!(batch[6..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn class_label_batch_multihot() {
        let ds = sample_ds();
        let y = ds.class_label_batch(&[0], 2);
        assert_eq!(y.len(), 20);
        assert_eq!(y[0], 1.0);
        assert_eq!(y[5], 1.0);
        assert_eq!(y.iter().filter(|&&v| v > 0.0).count(), 2);
    }

    #[test]
    fn subset_preserves_rows() {
        let ds = sample_ds();
        let sub = ds.subset(&[1, 1, 0]);
        assert_eq!(sub.len(), 3);
        assert_eq!(sub.features_of(0), ds.features_of(1));
        assert_eq!(sub.labels_of(2), ds.labels_of(0));
    }

    #[test]
    fn batch_ranges_cover_everything() {
        assert_eq!(batch_ranges(10, 4), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(batch_ranges(0, 4), vec![]);
        assert_eq!(batch_ranges(4, 4), vec![(0, 4)]);
    }
}
